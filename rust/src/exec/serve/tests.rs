use super::*;
use crate::arch::VtaConfig;
use crate::compiler::{compile_eltwise, Conv2dParams, EltwiseKind, MatmulParams, Requant};
use crate::exec::{CpuBackend, ExecError, Executor};
use crate::graph::{partition, Graph, Op, PartitionPolicy, Placement};
use crate::runtime::VtaRuntime;
use crate::util::{Tensor, XorShiftRng};

fn rand_t(seed: u64, shape: &[usize]) -> Tensor<i8> {
    let mut rng = XorShiftRng::new(seed);
    Tensor::from_vec(shape, rng.vec_i8(shape.iter().product(), -8, 8)).unwrap()
}

fn conv_p(ic: usize, oc: usize, relu: bool) -> Conv2dParams {
    Conv2dParams {
        h: 8,
        w: 8,
        ic,
        oc,
        k: 3,
        s: 1,
        requant: crate::compiler::Requant { shift: 6, relu },
    }
}

/// Two VTA convs with identical params but different weights →
/// distinct plans. A batch of three requests compiles each exactly
/// once and hits on every later lookup.
fn two_conv_graph() -> Graph {
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let c1 = g.add("c1", Op::Conv2d { p: conv_p(16, 16, true) }, &[x]).unwrap();
    g.set_weights(c1, rand_t(101, &[16, 16, 3, 3]));
    let c2 = g.add("c2", Op::Conv2d { p: conv_p(16, 16, false) }, &[c1]).unwrap();
    g.set_weights(c2, rand_t(102, &[16, 16, 3, 3]));
    let _p = g.add("pool", Op::MaxPool { k: 2, s: 2, pad: 0 }, &[c2]).unwrap();
    g
}

/// A small ResNet basic block: conv → conv, residual add, relu.
fn residual_block_graph() -> Graph {
    let p = conv_p(16, 16, false);
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let c1 = g.add("c1", Op::Conv2d { p }, &[x]).unwrap();
    g.set_weights(c1, rand_t(111, &[16, 16, 3, 3]));
    let c2 = g.add("c2", Op::Conv2d { p }, &[c1]).unwrap();
    g.set_weights(c2, rand_t(112, &[16, 16, 3, 3]));
    let add = g.add("add", Op::Add, &[c2, x]).unwrap();
    let _r = g.add("relu", Op::Relu, &[add]).unwrap();
    g
}

/// A ResNet-style tail with every registered VTA op class: conv,
/// residual add, standalone relu, gap, dense classifier.
fn mixed_op_graph() -> Graph {
    let p = conv_p(16, 16, false);
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let c1 = g.add("c1", Op::Conv2d { p: conv_p(16, 16, true) }, &[x]).unwrap();
    g.set_weights(c1, rand_t(121, &[16, 16, 3, 3]));
    let c2 = g.add("c2", Op::Conv2d { p }, &[c1]).unwrap();
    g.set_weights(c2, rand_t(122, &[16, 16, 3, 3]));
    let add = g.add("add", Op::Add, &[c2, x]).unwrap();
    let r = g.add("relu", Op::Relu, &[add]).unwrap();
    let gap = g.add("gap", Op::GlobalAvgPool, &[r]).unwrap();
    let fcp = MatmulParams { m: 1, k: 16, n: 10, requant: Requant { shift: 2, relu: false } };
    let fc = g.add("fc", Op::Dense { p: fcp }, &[gap]).unwrap();
    g.set_weights(fc, rand_t(123, &[10, 16]));
    g
}

fn engine(cap: usize) -> ServingEngine {
    ServingEngine::new(&VtaConfig::pynq(), 64 << 20, CpuBackend::Native, 2, cap)
}

#[test]
fn plan_cache_counts_hits_and_misses() {
    let cfg = VtaConfig::pynq();
    let mut g = two_conv_graph();
    partition(&mut g, &PartitionPolicy::paper(&cfg));

    let mut eng = engine(8);
    let inputs: Vec<_> = (0..3).map(|i| rand_t(200 + i, &[1, 16, 8, 8])).collect();
    let batch = eng.run_batch(&g, &inputs).unwrap();

    // Lowering ran once per unique VTA node, despite 3 requests x
    // 2 conv nodes = 6 lookups.
    assert_eq!(batch.cache.misses, 2, "one compile per unique (params, weights)");
    assert_eq!(batch.cache.hits, 4, "every later lookup hits");
    assert_eq!(batch.cache.evictions, 0);
    assert_eq!(eng.cached_plans(), 2);

    // A second (warm) batch never compiles.
    let warm = eng.run_batch(&g, &inputs).unwrap();
    assert_eq!(warm.cache.misses, 0);
    assert_eq!(warm.cache.hits, 6);
}

#[test]
fn plan_cache_evicts_lru_and_stays_correct() {
    let cfg = VtaConfig::pynq();
    let mut g = two_conv_graph();
    partition(&mut g, &PartitionPolicy::paper(&cfg));
    let input = rand_t(300, &[1, 16, 8, 8]);

    // Reference output from the serial executor.
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 64 << 20), CpuBackend::Native);
    let expect = ex.run(&g, &input).unwrap().output;

    // Capacity 1: the two conv plans thrash, evicting each other.
    let mut eng = engine(1);
    let r1 = eng.run_one(&g, &input).unwrap();
    let r2 = eng.run_one(&g, &input).unwrap();
    assert_eq!(r1.output, expect);
    assert_eq!(r2.output, expect, "eviction must not corrupt results");
    let s = eng.cache_stats();
    assert_eq!(s.hits, 0, "capacity 1 cannot retain either plan");
    assert_eq!(s.misses, 4);
    assert!(s.evictions >= 3, "thrashing must evict: {s:?}");
    assert_eq!(eng.cached_plans(), 1);
}

#[test]
fn eviction_releases_dram() {
    let cfg = VtaConfig::pynq();
    let mut g = two_conv_graph();
    partition(&mut g, &PartitionPolicy::paper(&cfg));
    let input = rand_t(310, &[1, 16, 8, 8]);

    let mut eng = engine(1);
    eng.run_one(&g, &input).unwrap();
    let one_plan = eng.cache_dram_bytes();
    eng.run_one(&g, &input).unwrap();
    // Still exactly one resident plan's worth of DRAM (same shapes
    // → same footprint), not an accumulating leak.
    assert_eq!(eng.cache_dram_bytes(), one_plan);
}

/// Satellite regression: the cache's incrementally tracked DRAM
/// residency stays consistent with the recomputed sum across
/// evict → recompile cycles of the same key, and flush zeroes it —
/// returning the runtime allocator to its pre-cache watermark.
#[test]
fn dram_accounting_survives_evict_and_reinsert() {
    let cfg = VtaConfig::pynq();
    let mut rt = VtaRuntime::new(&cfg, 64 << 20);
    let baseline_used = rt.dram.used();

    let key = |op_fp: u64, kind: &'static str| PlanKey {
        config_fp: 1,
        virtual_threads: 2,
        kind,
        op_fp,
    };
    let compile_add = |len: usize| {
        move |rt: &mut VtaRuntime| {
            compile_eltwise(rt, EltwiseKind::AddSat, len, 2).map_err(ExecError::PlanCache)
        }
    };

    let mut cache = PlanCache::new(1);
    assert_eq!(cache.dram_bytes(), 0);
    cache.get_or_compile(&mut rt, &key(0xA, "add"), compile_add(4096)).unwrap();
    let one_plan = cache.dram_bytes();
    assert!(one_plan > 0);
    assert_eq!(cache.dram_bytes(), cache.recomputed_dram_bytes());

    // Thrash two same-footprint keys through the single slot: each
    // round evicts and recompiles, and the tracked residency must
    // stay exact (no drift up or down).
    for round in 0..3 {
        cache.get_or_compile(&mut rt, &key(0xB, "add"), compile_add(4096)).unwrap();
        assert_eq!(cache.dram_bytes(), one_plan, "round {round}: B resident");
        assert_eq!(cache.dram_bytes(), cache.recomputed_dram_bytes(), "round {round}");
        cache.get_or_compile(&mut rt, &key(0xA, "add"), compile_add(4096)).unwrap();
        assert_eq!(cache.dram_bytes(), one_plan, "round {round}: A resident again");
        assert_eq!(cache.dram_bytes(), cache.recomputed_dram_bytes(), "round {round}");
    }
    let s = cache.stats();
    assert_eq!(s.misses, 7, "every lookup misses at capacity 1");
    assert_eq!(s.evictions, 6, "each recompile evicted the prior plan");

    // A different-footprint plan: the tracked count follows it.
    cache.get_or_compile(&mut rt, &key(0xC, "add"), compile_add(16 * 4096)).unwrap();
    assert_ne!(cache.dram_bytes(), one_plan);
    assert_eq!(cache.dram_bytes(), cache.recomputed_dram_bytes());

    // Flush: residency zero, allocator back at its watermark.
    cache.flush(&mut rt).unwrap();
    assert_eq!(cache.dram_bytes(), 0);
    assert_eq!(cache.recomputed_dram_bytes(), 0);
    assert_eq!(rt.dram.used(), baseline_used, "flush must return every DRAM byte");
}

#[test]
fn plan_keys_isolate_configs_weights_and_kinds() {
    // Two single-conv graphs with identical params but different
    // weights, plus a residual block for the ALU-op kinds.
    let build = |wseed: u64| {
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
        let c = g.add("c", Op::Conv2d { p: conv_p(16, 16, false) }, &[x]).unwrap();
        g.set_weights(c, rand_t(wseed, &[16, 16, 3, 3]));
        g
    };
    let g1 = build(400);
    let g2 = build(401);

    let pynq = engine(4);
    let mut wide_cfg = VtaConfig::pynq();
    wide_cfg.uop_buf_bytes *= 2;
    let wide = ServingEngine::new(&wide_cfg, 64 << 20, CpuBackend::Native, 2, 4);

    // Same op + weights under different hardware variants → keys
    // differ (a plan compiled for one variant is never replayed on
    // another).
    assert_ne!(pynq.plan_key(&g1, &g1.nodes[1]), wide.plan_key(&g1, &g1.nodes[1]));
    // Same config + op, different weights → keys differ (weights
    // are baked into the plan's DRAM image).
    assert_ne!(pynq.plan_key(&g1, &g1.nodes[1]), pynq.plan_key(&g2, &g2.nodes[1]));
    // Identical everything → same key (sharing is intended).
    assert_eq!(pynq.plan_key(&g1, &g1.nodes[1]), pynq.plan_key(&g1, &g1.nodes[1]));

    // Different op kinds over the same shape → different keys.
    let rb = residual_block_graph();
    let add = rb.nodes.iter().find(|n| n.op.kind() == "add").unwrap();
    let relu = rb.nodes.iter().find(|n| n.op.kind() == "relu").unwrap();
    let ka = pynq.plan_key(&rb, add);
    let kr = pynq.plan_key(&rb, relu);
    assert_ne!(ka, kr);
    assert_eq!(ka.kind, "add");
    assert_eq!(kr.kind, "relu");
}

/// Batched serving produces exactly the serial executor's outputs
/// on a ResNet basic block — per request, bit-identical.
#[test]
fn batched_matches_sequential_executor_on_residual_block() {
    let cfg = VtaConfig::pynq();
    let mut g = residual_block_graph();
    partition(&mut g, &PartitionPolicy::paper(&cfg));
    let inputs: Vec<_> = (0..3).map(|i| rand_t(500 + i, &[1, 16, 8, 8])).collect();

    let mut eng = engine(8);
    let batch = eng.run_batch(&g, &inputs).unwrap();

    for (i, input) in inputs.iter().enumerate() {
        let mut ex = Executor::new(VtaRuntime::new(&cfg, 64 << 20), CpuBackend::Native);
        let expect = ex.run(&g, input).unwrap().output;
        assert_eq!(batch.outputs[i], expect, "request {i} diverged from serial executor");
    }

    // The pipelined model can only help, and with both CPU and VTA
    // work in flight across 3 requests it must strictly help
    // (guarded on the CPU side having measurable duration, so a
    // pathological zero-resolution clock can't flake the test).
    assert!(batch.pipelined_seconds <= batch.serial_seconds + 1e-12);
    let cpu_seconds: f64 = batch
        .per_request
        .iter()
        .flatten()
        .filter(|n| n.placement != Placement::Vta)
        .map(|n| n.wall.as_secs_f64())
        .sum();
    if cpu_seconds > 0.0 {
        assert!(
            batch.pipelined_seconds < batch.serial_seconds,
            "no overlap found: pipelined {} vs serial {}",
            batch.pipelined_seconds,
            batch.serial_seconds
        );
    }
    assert!(batch.throughput() > 0.0);
    assert!(batch.latency_percentile(0.99) >= batch.latency_percentile(0.50));
}

/// Op-generic caching: a graph with conv, add, relu, and dense all
/// offloaded compiles each unique node exactly once and reuses
/// every plan across the batch — the acceptance scenario of the
/// operator-registry redesign.
#[test]
fn mixed_op_kinds_cache_and_match_serial_executor() {
    let cfg = VtaConfig::pynq();
    let mut g = mixed_op_graph();
    let policy = PartitionPolicy::offload_all(&cfg);
    let (vta_nodes, _) = partition(&mut g, &policy);
    assert_eq!(vta_nodes, 5, "conv x2 + add + relu + dense offload");

    let inputs: Vec<_> = (0..3).map(|i| rand_t(600 + i, &[1, 16, 8, 8])).collect();
    let mut eng = engine(16);
    let batch = eng.run_batch(&g, &inputs).unwrap();

    // One compile per unique VTA node; every later lookup hits.
    assert_eq!(batch.cache.misses, 5);
    assert_eq!(batch.cache.hits, 10);
    let kinds = eng.cached_kinds();
    assert_eq!(kinds.get("conv2d"), Some(&2));
    assert_eq!(kinds.get("add"), Some(&1));
    assert_eq!(kinds.get("relu"), Some(&1));
    assert_eq!(kinds.get("dense"), Some(&1));

    // Bit-identical to the serial executor (which itself verifies
    // against the CPU-only reference in the exec tests).
    for (i, input) in inputs.iter().enumerate() {
        let mut ex = Executor::new(VtaRuntime::new(&cfg, 64 << 20), CpuBackend::Native);
        let expect = ex.run(&g, input).unwrap().output;
        assert_eq!(batch.outputs[i], expect, "request {i} diverged");
    }

    // Warm batch: pure replay across every op kind.
    let warm = eng.run_batch(&g, &inputs).unwrap();
    assert_eq!(warm.cache.misses, 0);
    assert_eq!(warm.cache.hits, 15);
}

/// Eviction works across mixed op kinds: a cache smaller than the
/// working set thrashes but stays correct.
#[test]
fn mixed_op_kinds_evict_and_stay_correct() {
    let cfg = VtaConfig::pynq();
    let mut g = mixed_op_graph();
    partition(&mut g, &PartitionPolicy::offload_all(&cfg));
    let input = rand_t(700, &[1, 16, 8, 8]);

    let mut ex = Executor::new(VtaRuntime::new(&cfg, 64 << 20), CpuBackend::Native);
    let expect = ex.run(&g, &input).unwrap().output;

    let mut eng = engine(2);
    let r1 = eng.run_one(&g, &input).unwrap();
    let r2 = eng.run_one(&g, &input).unwrap();
    assert_eq!(r1.output, expect);
    assert_eq!(r2.output, expect, "eviction must not corrupt mixed-kind results");
    let s = eng.cache_stats();
    assert_eq!(s.misses, 10, "5 VTA nodes x 2 requests all miss at capacity 2");
    assert!(s.evictions >= 8, "thrashing must evict: {s:?}");
    assert!(eng.cached_plans() <= 2);
}

/// The schedule respects dependences: no request finishes before
/// the sum of its critical-path durations, and completions are
/// bounded by the makespan.
#[test]
fn pipeline_schedule_is_sane() {
    let cfg = VtaConfig::pynq();
    let mut g = residual_block_graph();
    partition(&mut g, &PartitionPolicy::paper(&cfg));
    let inputs: Vec<_> = (0..4).map(|i| rand_t(600 + i, &[1, 16, 8, 8])).collect();

    let mut eng = engine(8);
    let batch = eng.run_batch(&g, &inputs).unwrap();
    let model = pipeline_schedule(&g, &batch.per_request);

    assert_eq!(model.completion_seconds.len(), 4);
    for (r, &c) in model.completion_seconds.iter().enumerate() {
        assert!(c <= model.makespan_seconds + 1e-12);
        // Completions are at least the request's own chain time on
        // the critical path (here: the whole graph is one chain
        // except the shortcut).
        let own: f64 = batch.per_request[r]
            .iter()
            .map(|n| n.wall.as_secs_f64() + n.sim_seconds)
            .sum();
        assert!(c <= model.serial_seconds + 1e-12);
        assert!(own > 0.0);
    }
    // Makespan is monotone in batch size: a prefix of requests
    // cannot take longer than the full batch.
    let prefix = pipeline_schedule(&g, &batch.per_request[..2]);
    assert!(prefix.makespan_seconds <= model.makespan_seconds + 1e-12);
}

// ---------------------------------------------------------------------
// Multi-device scheduler.
// ---------------------------------------------------------------------

fn scheduler(cfg: &VtaConfig, devices: usize, max_batch: usize, deadline: f64) -> Scheduler {
    let opts = SchedulerOptions {
        devices,
        max_batch,
        batch_deadline: deadline,
        cache_capacity: 16,
        virtual_threads: 2,
        dram_size: 64 << 20,
    };
    Scheduler::new(cfg, CpuBackend::Native, opts)
}

/// The tentpole compile-once-per-pool property: a 3-replica pool
/// serving a mixed-op graph compiles each unique plan exactly once
/// (pool-level misses == unique keys, not devices × keys), replicas
/// hold identical residency, and every output is bit-identical to the
/// single-device engine.
#[test]
fn pool_compiles_once_and_matches_single_device_engine() {
    let cfg = VtaConfig::pynq();
    let mut g = mixed_op_graph();
    partition(&mut g, &PartitionPolicy::offload_all(&cfg));
    let inputs: Vec<_> = (0..6).map(|i| rand_t(900 + i, &[1, 16, 8, 8])).collect();

    let mut eng = engine(16);
    let expect = eng.run_batch(&g, &inputs).unwrap();

    let mut sched = scheduler(&cfg, 3, 2, 0.0);
    for input in &inputs {
        sched.submit(0.0, input.clone());
    }
    let report = sched.run(&g).unwrap();

    assert_eq!(report.outputs.len(), inputs.len());
    for (i, out) in report.outputs.iter().enumerate() {
        assert_eq!(out, &expect.outputs[i], "request {i} diverged from the engine");
    }
    // 5 unique VTA plans; the pool compiled each exactly once even
    // though 3 replicas each need it resident.
    assert_eq!(report.cache.misses, 5, "one compile per unique plan key per POOL");
    assert_eq!(sched.cached_plans(), 5);
    assert_eq!(sched.cache_dram_bytes(), eng.cache_dram_bytes(), "replica residency matches");

    // 6 requests at t=0, max_batch 2 → 3 batches over 3 replicas: all
    // replicas served work, and the modeled span beats one device
    // doing the batches back to back.
    assert_eq!(report.batches.len(), 3);
    let used: std::collections::HashSet<usize> =
        report.batches.iter().map(|b| b.device).collect();
    assert_eq!(used.len(), 3, "least-loaded dispatch must spread 3 batches over 3 replicas");
    let serial_sum: f64 = report.batches.iter().map(|b| b.finish - b.start).sum();
    assert!(report.makespan_seconds < serial_sum, "pool must overlap batches in simulated time");

    // Warm drain: no further compiles.
    for input in &inputs {
        sched.submit(0.0, input.clone());
    }
    let warm = sched.run(&g).unwrap();
    assert_eq!(warm.cache.misses, 0, "warm pool drain must not re-lower");
    for (i, out) in warm.outputs.iter().enumerate() {
        assert_eq!(out, &expect.outputs[i], "warm request {i} diverged");
    }
}

/// Dynamic batching: max_batch closes full batches, the simulated
/// deadline splits sparse streams, and the final partial batch
/// flushes at stream end.
#[test]
fn dynamic_batching_respects_max_batch_and_deadline() {
    let cfg = VtaConfig::pynq();
    let mut g = two_conv_graph();
    partition(&mut g, &PartitionPolicy::paper(&cfg));

    // Five requests at t = 0 with max_batch 2 → batches of 2/2/1.
    let mut sched = scheduler(&cfg, 1, 2, 1.0);
    for i in 0..5 {
        sched.submit(0.0, rand_t(1000 + i, &[1, 16, 8, 8]));
    }
    let r = sched.run(&g).unwrap();
    let sizes: Vec<usize> = r.batches.iter().map(|b| b.size).collect();
    assert_eq!(sizes, vec![2, 2, 1]);
    // The trailing partial batch flushes at stream end (t = 0), not
    // after the full 1s deadline.
    assert_eq!(r.batches[2].ready, 0.0);

    // A sparse stream: the second request arrives past the first's
    // deadline, so they cannot share a batch even with room to spare.
    let mut sched = scheduler(&cfg, 1, 8, 0.5e-3);
    sched.submit(0.0, rand_t(1100, &[1, 16, 8, 8]));
    sched.submit(2e-3, rand_t(1101, &[1, 16, 8, 8]));
    let r = sched.run(&g).unwrap();
    assert_eq!(r.batches.len(), 2, "deadline must split the sparse stream");
    assert_eq!(r.batches[0].size, 1);
    // The first batch dispatched at its deadline, the second at
    // stream end (its own arrival).
    assert!((r.batches[0].ready - 0.5e-3).abs() < 1e-12);
    assert!((r.batches[1].ready - 2e-3).abs() < 1e-12);
    // Latencies account the batching wait: request 0 completed no
    // earlier than its deadline.
    assert!(r.completions[0] >= 0.5e-3);
    // Queue depth counts *arrived* undispatched requests: request 1
    // had not arrived when batch 0 dispatched, so the gauge never saw
    // a backlog of 2.
    assert_eq!(r.metrics.queue.max_depth(), 1, "not-yet-arrived requests must not count");
}

/// Throughput scales with pool size: the same request stream drains
/// in no more simulated time on a larger pool, and the per-device
/// utilization + queue metrics are sane.
#[test]
fn pool_scaling_is_monotone_and_metrics_are_sane() {
    let cfg = VtaConfig::pynq();
    let mut g = two_conv_graph();
    partition(&mut g, &PartitionPolicy::paper(&cfg));
    let inputs: Vec<_> = (0..8).map(|i| rand_t(1200 + i, &[1, 16, 8, 8])).collect();

    let mut spans = Vec::new();
    let mut all_outputs: Vec<Vec<Tensor<i8>>> = Vec::new();
    for devices in [1usize, 2, 4] {
        let mut sched = scheduler(&cfg, devices, 2, 0.0);
        for input in &inputs {
            sched.submit(0.0, input.clone());
        }
        let r = sched.run(&g).unwrap();
        assert_eq!(r.device_busy.len(), devices);
        assert_eq!(r.metrics.devices.len(), devices);
        // Queue depth starts at the full backlog and is sampled at
        // every dispatch.
        assert_eq!(r.metrics.queue.max_depth(), inputs.len());
        assert_eq!(r.metrics.queue.samples().len(), r.batches.len());
        for d in 0..devices {
            let u = r.utilization(d);
            assert!((0.0..=1.0).contains(&u), "utilization out of range: {u}");
            assert_eq!(r.metrics.devices[d].busy_seconds, r.device_busy[d]);
        }
        let served: u64 = r.metrics.devices.iter().map(|c| c.requests).sum();
        assert_eq!(served, inputs.len() as u64);
        assert!(r.latency_percentile(0.99) >= r.latency_percentile(0.50));
        spans.push(r.makespan_seconds);
        all_outputs.push(r.outputs);
    }
    // VTA-dominated spans shrink (weakly) as replicas are added; the
    // 4-replica pool must strictly beat one device on 4 batches.
    assert!(spans[1] <= spans[0] + 1e-9, "2 devices slower than 1: {spans:?}");
    assert!(spans[2] <= spans[1] + 1e-9, "4 devices slower than 2: {spans:?}");
    assert!(spans[2] < spans[0], "4 devices must beat 1 outright: {spans:?}");
    // Pool size must never change results.
    for outs in &all_outputs[1..] {
        assert_eq!(outs, &all_outputs[0], "pool size changed outputs");
    }
}

// ---------------------------------------------------------------------
// Pipeline partitioner.
// ---------------------------------------------------------------------

/// Stage structure invariants of any cut: contiguous level coverage,
/// every node exactly once, exact boundary live sets with adjacent
/// stages agreeing (`consumes[s] == carries[s-1]` — the same cut seen
/// from both sides), and byte-accurate handoff accounting.
#[test]
fn pipeline_partition_live_sets_are_exact() {
    let cfg = VtaConfig::pynq();
    let mut g = residual_block_graph();
    partition(&mut g, &PartitionPolicy::offload_all(&cfg));
    // Levels: in=0, c1=1, c2=2, add=3, relu=4. Cutting at level 2
    // leaves the residual input `x` live across the cut alongside c1.
    let p = PipelinePartition::from_cuts(&cfg, &g, &[2]);
    assert_eq!(p.len(), 2);
    assert_eq!(p.stages[0].levels, (0, 2));
    assert_eq!(p.stages[1].levels, (2, 5));
    assert_eq!(p.stages[0].nodes, vec![0, 1]);
    assert_eq!(p.stages[1].nodes, vec![2, 3, 4]);
    assert!(p.stages[0].consumes.is_empty(), "stage 0 receives nothing");
    assert!(p.stages[1].carries.is_empty(), "last stage forwards nothing");
    // The cut's live set: c1 feeds c2, and the residual x skips ahead
    // to the add — both must cross, nothing else.
    assert_eq!(p.stages[0].carries, vec![0, 1]);
    assert_eq!(p.stages[1].consumes, p.stages[0].carries);
    // int8: one byte per element; two [1,16,8,8] tensors cross.
    assert_eq!(p.stages[0].handoff_bytes, 2 * 16 * 8 * 8);
    assert_eq!(p.stages[1].handoff_bytes, 0);
    assert!(p.stages[0].handoff_seconds > 0.0);

    // Every node appears in exactly one stage, and the balanced
    // variant keeps the same invariants for every k (clamping k past
    // the level count).
    for k in 1..=7 {
        let p = PipelinePartition::balanced(&cfg, &g, k);
        assert!(p.len() <= 5, "k={k} cannot exceed the level count");
        assert_eq!(p.len(), k.min(5));
        let mut seen: Vec<usize> = p.stages.iter().flat_map(|s| s.nodes.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..g.nodes.len()).collect::<Vec<_>>(), "k={k} must cover the graph");
        for w in p.stages.windows(2) {
            assert_eq!(w[1].consumes, w[0].carries, "adjacent stages disagree on the cut");
            assert_eq!(w[0].levels.1, w[1].levels.0, "stages must tile the levels");
        }
        assert!(p.stages[0].consumes.is_empty());
        assert!(p.stages.last().unwrap().carries.is_empty());
    }
}

/// The balancer minimizes the bottleneck: against a deliberately
/// lopsided cut of the same stage count it never has a worse
/// bottleneck stage, and its modeled streaming makespan is no worse.
#[test]
fn pipeline_balancer_beats_unbalanced_cut() {
    let cfg = VtaConfig::pynq();
    let mut g = mixed_op_graph();
    partition(&mut g, &PartitionPolicy::offload_all(&cfg));
    let balanced = PipelinePartition::balanced(&cfg, &g, 2);
    // Lopsided: stage 0 gets only the input level; both convs, the
    // ALU ops, and the classifier all pile into stage 1.
    let lopsided = PipelinePartition::from_cuts(&cfg, &g, &[1]);
    assert_eq!(balanced.len(), lopsided.len());
    assert!(
        balanced.bottleneck_seconds() <= lopsided.bottleneck_seconds(),
        "balancer produced a worse bottleneck: {} vs {}",
        balanced.bottleneck_seconds(),
        lopsided.bottleneck_seconds()
    );
    let (b, l) = (balanced.modeled_makespan(16), lopsided.modeled_makespan(16));
    assert!(b <= l + 1e-12, "balanced makespan {b} worse than lopsided {l}");

    // The modeled makespan behaves like a pipeline: monotone in the
    // request count, and for one request it is exactly the sum of
    // stage times plus interior handoffs.
    let one = balanced.modeled_makespan(1);
    let sum: f64 = balanced.stages.iter().map(|s| s.model_seconds + s.handoff_seconds).sum();
    assert!((one - sum).abs() < 1e-12, "single-request makespan must be the serial chain");
    assert!(balanced.modeled_makespan(2) >= one);
    // Deep streams amortize toward the bottleneck: 16 requests cost
    // less than 16 serial chains.
    assert!(balanced.modeled_makespan(16) < 16.0 * one);
}

/// The simulated pipeline scheduler is bit-exact against the
/// single-replica engine, its per-stage counters account every
/// request, and its modeled stream makespan beats the 1-stage
/// scheduler's on a multi-request trace (the pipelining win).
#[test]
fn pipeline_scheduler_matches_engine_and_pipelines() {
    let cfg = VtaConfig::pynq();
    let mut g = mixed_op_graph();
    partition(&mut g, &PartitionPolicy::offload_all(&cfg));
    let inputs: Vec<_> = (0..6).map(|i| rand_t(1400 + i, &[1, 16, 8, 8])).collect();

    let mut eng = engine(16);
    let expect = eng.run_batch(&g, &inputs).unwrap();

    let mut opts = PipelineOptions::new(2);
    opts.dram_size = 64 << 20;
    let part = PipelinePartition::balanced(&cfg, &g, 2);
    let mut sched = PipelineScheduler::new(&cfg, CpuBackend::Native, opts);
    let r = sched.run(&g, &part, &inputs).unwrap();

    assert_eq!(r.outputs.len(), inputs.len());
    for (i, out) in r.outputs.iter().enumerate() {
        assert_eq!(out, &expect.outputs[i], "request {i} diverged from the engine");
    }
    // Counters: every stage saw every request; handoff totals follow
    // the partition; plan compiles split across the two independent
    // caches without overlap (5 unique plans in this graph).
    assert_eq!(r.metrics.stages.len(), 2);
    for (s, c) in r.metrics.stages.iter().enumerate() {
        assert_eq!(c.requests, inputs.len() as u64, "stage {s} miscounted requests");
        assert_eq!(c.nodes, part.stages[s].nodes.len() as u64);
        assert_eq!(c.handoff_bytes, inputs.len() as u64 * part.stages[s].handoff_bytes);
    }
    let misses: u64 = r.cache.iter().map(|c| c.misses).sum();
    assert_eq!(misses, 5, "each stage compiles exactly its own plans, once");
    // Pipelining: completions are ordered, and the 2-stage modeled
    // makespan beats the 1-stage (serial chain) pipeline on 6 requests.
    for w in r.completions.windows(2) {
        assert!(w[0] <= w[1] + 1e-12, "completions must be non-decreasing");
    }
    let mut opts1 = PipelineOptions::new(1);
    opts1.dram_size = 64 << 20;
    let part1 = PipelinePartition::balanced(&cfg, &g, 1);
    let mut sched1 = PipelineScheduler::new(&cfg, CpuBackend::Native, opts1);
    let r1 = sched1.run(&g, &part1, &inputs).unwrap();
    assert_eq!(r1.outputs, r.outputs, "stage count must never change results");
    assert!(
        r.makespan_seconds < r1.makespan_seconds,
        "2-stage stream ({}) must beat the serial chain ({})",
        r.makespan_seconds,
        r1.makespan_seconds
    );
}

// ---------------------------------------------------------------------
// Loadgen measurement fixes.
// ---------------------------------------------------------------------

/// Per-step arrival seeds come from the splitmix64 stream: step 0 is
/// no longer the raw user seed, same-seed steps never collide (the
/// underlying counter-to-seed map is a bijection), and adjacent user
/// seeds get disjoint step streams — the XOR-of-multiples scheme
/// guaranteed none of these.
#[test]
fn loadgen_step_seeds_are_mixed_and_collision_free() {
    use super::loadgen::step_seed;
    // Regression: the old `seed ^ (idx * C)` made step 0's stream the
    // raw seed (0 here). Splitmix64 maps only 0 to 0, and the counter
    // is offset by a nonzero gamma, so step 0 of seed 0 is nonzero.
    assert_ne!(step_seed(0, 0), 0);
    for seed in [0u64, 1, 0x10ad, u64::MAX] {
        let stream: Vec<u64> = (0..8).map(|i| step_seed(seed, i)).collect();
        let mut dedup = stream.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), stream.len(), "seed {seed}: step seeds must be distinct");
        // Disjoint from the neighboring user seed's stream (bijective
        // mix of `seed + (i+1)·gamma`: equality would need the seeds
        // to differ by a small multiple of the odd 64-bit gamma).
        let other: Vec<u64> = (0..8).map(|i| step_seed(seed.wrapping_add(1), i)).collect();
        assert!(
            stream.iter().all(|s| !other.contains(s)),
            "seed {seed}: adjacent seeds must not share step streams"
        );
    }
}

/// An empty sample set reports NaN ("no samples"), never a fake zero
/// latency; non-empty sets defer to the shared percentile.
#[test]
fn loadgen_percentiles_report_nan_on_no_samples() {
    use super::loadgen::percentile_or_nan;
    for p in [0.0, 0.5, 0.99, 0.999, 1.0] {
        assert!(percentile_or_nan(&[], p).is_nan(), "empty slice must be NaN at p={p}");
    }
    let s = [1.0, 2.0, 3.0];
    for p in [0.0, 0.5, 0.99, 1.0] {
        assert_eq!(percentile_or_nan(&s, p), crate::util::percentile_sorted(&s, p));
    }
    // The report-level view: an all-shed step is distinguishable from
    // a zero-latency one.
    let mut shed = StepReport {
        qps: 100.0,
        offered: 4,
        accepted: 0,
        rejected: 4,
        p50: f64::NAN,
        p99: f64::NAN,
        p999: f64::NAN,
        slo_attainment: 0.0,
        throughput_rps: 0.0,
        wall: std::time::Duration::ZERO,
    };
    assert!(!shed.has_samples());
    shed.p50 = 0.0;
    assert!(shed.has_samples(), "a genuine zero-latency sample is still a sample");
}

/// Regression for the step-clock bug: the measured wall span opens at
/// the first *submit*, so a large first exponential gap (pre-arrival
/// idle) no longer counts as load and can't deflate `throughput_rps`.
#[test]
fn loadgen_wall_excludes_first_arrival_gap() {
    use super::loadgen::{arrival_gap, step_seed};
    let cfg = VtaConfig::pynq();
    let mut g = two_conv_graph();
    partition(&mut g, &PartitionPolicy::paper(&cfg));

    // Deterministically find a seed whose step-0 first gap at 2 rps is
    // substantial (0.5–1.5 s): the old code's wall necessarily
    // included it, the fixed code's must not.
    let qps = 2.0;
    let (seed, gap) = (0u64..)
        .find_map(|seed| {
            let mut rng = XorShiftRng::new(step_seed(seed, 0));
            let gap = arrival_gap(&mut rng, qps);
            (0.5..1.5).contains(&gap).then_some((seed, gap))
        })
        .expect("some seed yields a mid-range first gap");

    let mut topts = ThreadedOptions::new(1);
    topts.dram_size = 64 << 20;
    let lopts = LoadgenOptions {
        steps: vec![QpsStep { qps, requests: 1 }],
        slo: 10.0,
        seed,
    };
    let (report, _) = run_threaded(
        &cfg,
        &topts,
        &crate::dse::records::TuningRecords::new(),
        &g,
        |handle| open_loop(handle, &lopts, |i| rand_t(1500 + i, &[1, 16, 8, 8])),
    )
    .unwrap();

    let step = &report.steps[0];
    assert_eq!(step.accepted, 1);
    assert!(step.has_samples());
    let wall = step.wall.as_secs_f64();
    // The single request's service time is milliseconds; the ≥0.5 s
    // idle gap before it must be excluded from the span.
    assert!(
        wall < gap,
        "wall {wall}s still includes the {gap}s pre-first-arrival idle"
    );
    assert!(step.throughput_rps > 1.0 / gap, "throughput still deflated by the idle gap");
}
