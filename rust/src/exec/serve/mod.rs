//! The serving runtime: a JIT **compiled-plan cache**, a **pipelined,
//! batched** single-device engine, and a **multi-device scheduler**
//! over a pool of accelerator replicas.
//!
//! The paper's runtime hides memory latency behind compute with
//! explicit task-level pipeline parallelism (§2.3) and reuses JIT'd
//! micro-kernels through a DRAM-resident cache (§3.2). This module
//! lifts both ideas from single-kernel to whole-graph granularity —
//! for **every operator in the registry** — and then from one device
//! to many:
//!
//! * [`cache`] — [`PlanCache`]: an LRU cache of
//!   [`CompiledNode`](crate::compiler::CompiledNode)s keyed by
//!   ([`crate::arch::VtaConfig`] fingerprint, virtual threads,
//!   operator kind, operator fingerprint). Lowering a VTA node happens
//!   **once** per key; every later inference replays the sealed
//!   streams. Hit/miss/eviction counters mirror the micro-op cache's
//!   (ablation A2), and DRAM residency is tracked explicitly.
//! * [`schedule`] — [`pipeline_schedule`]: replay measured per-node
//!   durations (host wall for CPU nodes, simulated cycles ÷ clock for
//!   VTA nodes) against a two-resource, double-buffered dependence
//!   schedule — the graph-level analogue of the two SRAM contexts in
//!   §4.3's virtual threading.
//! * [`report`] — [`ServeReport`] / [`BatchReport`]: per-request and
//!   per-batch outputs, model times, cache counters, latency
//!   percentiles (via the one shared interpolating percentile in
//!   [`crate::util`]).
//! * [`engine`] — [`ServingEngine`]: the single-device
//!   compile-once/run-many front-end ([`ServingEngine::run_one`] /
//!   [`ServingEngine::run_batch`]).
//! * [`scheduler`] — [`Scheduler`]: the multi-device runtime. A
//!   request queue with **dynamic batching** (`max_batch` +
//!   `batch_deadline`, both in simulated time) feeds **least-loaded
//!   dispatch** across a [`DevicePool`](crate::runtime::DevicePool) of
//!   replicas; per-device simulated clocks advance independently, so
//!   modeled throughput genuinely scales with pool size. Per-device
//!   plan caches are driven in **lockstep** from a shared compile-once
//!   path: a plan is lowered exactly once per pool and byte-replicated
//!   ([`crate::compiler::CompiledNode::replicate_to`]) onto every
//!   replica. Queue depth, per-device utilization, and latency
//!   percentiles export through [`crate::metrics::PoolMetrics`].

//!
//! Two later additions promote the pool from simulated to real
//! concurrency:
//!
//! * [`threaded`](self) — the **real-threads** pool
//!   ([`run_threaded`] / [`serve_trace`]): one OS worker thread per
//!   replica, a bounded MPMC queue with admission control, and
//!   cross-thread plan sharing via a publish-barrier event log. The
//!   simulated [`Scheduler`] stays on as its deterministic oracle —
//!   for any trace, outputs are bit-identical and pool-level cache
//!   counters match.
//! * [`open_loop`] — open-loop Poisson load generation (target-QPS
//!   ramps, p50/p99/p99.9 latency, SLO attainment) against the
//!   threaded pool.
//!
//! And one generalization from N identical replicas to a mixed fleet:
//!
//! * [`fleet`] — **heterogeneous fleet serving**: a
//!   [`HeterogeneousPool`](crate::runtime::HeterogeneousPool) of
//!   per-replica configs grouped by variant, a cost-aware
//!   [`Router`](fleet::Router) assigning each workload class to its
//!   best config group, group-wise lockstep plan caches (simulated)
//!   and per-group plan directories (threaded), all deployed from a
//!   [`FleetSpec`](fleet::FleetSpec) that `vta dse --fleet` searches
//!   for and `vta serve --fleet` consumes.
//! * [`PipelineScheduler`] / [`run_pipeline_threaded`] — **graph-level
//!   pipeline parallelism**: one model split across pool replicas into
//!   roofline-balanced contiguous stage groups of its ASAP levels
//!   ([`PipelinePartition`]), stage-per-replica execution with the
//!   boundary live set as the only cross-device (DRAM) traffic, and
//!   multiple requests in flight so streamed latency approaches
//!   `max(stage)` instead of `sum(stages)` — again simulated oracle +
//!   real threads, bit-exact.

mod cache;
mod engine;
pub mod fleet;
mod loadgen;
mod pipeline;
mod queue;
mod report;
mod run;
mod schedule;
mod scheduler;
mod threaded;

pub use cache::{plan_key_for, PlanCache, PlanCacheStats, PlanKey};
pub use engine::ServingEngine;
pub use loadgen::{open_loop, LoadReport, LoadgenOptions, QpsStep, StepReport};
pub use pipeline::{
    run_pipeline_threaded, PipelineOptions, PipelinePartition, PipelineReport, PipelineScheduler,
    PipelineStage, PipelineThreadedReport,
};
pub use report::{BatchReport, ServeReport};
pub use schedule::{pipeline_schedule, PipelineModel};
pub use scheduler::{BatchRecord, PoolReport, Scheduler, SchedulerOptions};
pub use threaded::{
    run_threaded, serve_trace, Completion, PoolHandle, SubmitRejected, ThreadedOptions,
    ThreadedReport,
};

// Fingerprint helpers live with the operator registry; re-exported
// here for API continuity (and python/compile/synth.py parity).
pub use crate::compiler::op::{config_fingerprint, fnv1a64, weights_fingerprint};

#[cfg(test)]
mod tests;
