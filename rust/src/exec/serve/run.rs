//! The shared graph walker of the serving runtime: one staged
//! execution loop used by both the single-device engine and the
//! multi-device scheduler, so the two disciplines stay bit-identical
//! **by construction** (the property the determinism suites assert).
//! Only the "produce a compiled plan and run it" step differs between
//! them — closure-driven plan cache vs. lockstep pool caches — so that
//! step is the trait.

use super::super::executor::{exec_cpu_node, CpuBackend, ExecError, NodeReport};
use super::cache::{plan_key_for, PlanKey};
use crate::compiler::op::op_impl;
use crate::compiler::ScheduleChoice;
use crate::dse::records::TuningRecords;
use crate::graph::{Graph, Placement};
use crate::sim::SimStats;
use crate::util::Tensor;
use std::collections::HashMap;
use std::time::Instant;

/// How a serving front-end executes one VTA-resident node. Implemented
/// by [`ServingEngine`](super::ServingEngine) (plan cache over one
/// runtime) and by the scheduler's per-dispatch device view (lockstep
/// caches + a chosen pool replica).
pub(crate) trait VtaNodeExec {
    /// Simulated clock of the executing device (Hz).
    fn clock_hz(&self) -> f64;

    /// The CPU backend for CPU-resident nodes.
    fn cpu_mut(&mut self) -> &mut CpuBackend;

    /// Compile (or fetch) node `id`'s plan and execute it on the
    /// accelerator.
    fn exec_vta_node(
        &mut self,
        g: &Graph,
        id: usize,
        key: &PlanKey,
        schedule: Option<ScheduleChoice>,
        inputs: &[&Tensor<i8>],
    ) -> Result<(Tensor<i8>, SimStats), ExecError>;
}

/// The plan key of every VTA-resident node, `None` elsewhere
/// (operator fingerprints hash the full weight image — computed once
/// per graph, not once per request).
pub(crate) fn plan_keys_for(
    config_fp: u64,
    virtual_threads: usize,
    g: &Graph,
) -> Vec<Option<PlanKey>> {
    g.nodes
        .iter()
        .map(|node| {
            (node.placement == Placement::Vta)
                .then(|| plan_key_for(config_fp, virtual_threads, g, node))
        })
        .collect()
}

/// The tuned schedule of every VTA-resident node under `records`
/// (the record lookup hashes the operator's debug form — once per
/// graph, like the plan keys).
pub(crate) fn tuned_schedules_for(
    records: &TuningRecords,
    config_fp: u64,
    virtual_threads: usize,
    g: &Graph,
) -> Vec<Option<ScheduleChoice>> {
    if records.is_empty() {
        return vec![None; g.nodes.len()];
    }
    g.nodes
        .iter()
        .map(|node| {
            if node.placement == Placement::Vta {
                let entry = op_impl(&node.op);
                records.lookup(config_fp, virtual_threads, entry.schedule_fingerprint(node))
            } else {
                None
            }
        })
        .collect()
}

/// Execute the graph once, in topological stages: input nodes take the
/// request tensor, VTA nodes go through [`VtaNodeExec::exec_vta_node`],
/// CPU nodes through the shared CPU backend. `stage_order`, `keys`,
/// and `schedules` are precomputed per graph so batches amortize them.
/// Returns the output and per-node records indexed by node id.
pub(crate) fn run_graph<E: VtaNodeExec>(
    ex: &mut E,
    g: &Graph,
    input: &Tensor<i8>,
    stage_order: &[Vec<usize>],
    keys: &[Option<PlanKey>],
    schedules: &[Option<ScheduleChoice>],
) -> Result<(Tensor<i8>, Vec<NodeReport>), ExecError> {
    let seed = HashMap::new();
    let (mut values, reports) =
        run_graph_partial(ex, g, Some(input), stage_order, keys, schedules, &seed)?;
    let out_id = g.output().expect("non-empty graph");
    Ok((
        values[out_id].take().unwrap(),
        reports.into_iter().map(|r| r.expect("stages cover every node")).collect(),
    ))
}

/// Execute a *subset* of the graph — the pipeline-parallel variant of
/// [`run_graph`]. `level_order` names the nodes to execute (grouped in
/// dependence order, e.g. one pipeline stage's slice of the ASAP
/// levels); `seed_values` carries the live tensors handed off from
/// earlier pipeline stages (the inter-stage DRAM handoff contract:
/// every value a node here consumes is either produced here or
/// seeded). `input` is the request tensor for input nodes — only the
/// first pipeline stage has any, so later stages pass `None`.
///
/// Per-node execution is **identical** to [`run_graph`] (which
/// delegates here with the full stage order and no seeds) — that
/// shared body is what makes pipelined execution bit-exact against the
/// single-replica engine by construction.
///
/// Returns the value table (`Some` for executed + seeded nodes) and
/// per-node reports indexed by node id (`Some` for executed nodes).
pub(crate) fn run_graph_partial<E: VtaNodeExec>(
    ex: &mut E,
    g: &Graph,
    input: Option<&Tensor<i8>>,
    level_order: &[Vec<usize>],
    keys: &[Option<PlanKey>],
    schedules: &[Option<ScheduleChoice>],
    seed_values: &HashMap<usize, Tensor<i8>>,
) -> Result<(Vec<Option<Tensor<i8>>>, Vec<Option<NodeReport>>), ExecError> {
    let clock_hz = ex.clock_hz();
    let mut values: Vec<Option<Tensor<i8>>> = vec![None; g.nodes.len()];
    for (&id, v) in seed_values {
        values[id] = Some(v.clone());
    }
    let mut reports: Vec<Option<NodeReport>> = (0..g.nodes.len()).map(|_| None).collect();

    for stage in level_order {
        for &id in stage {
            let node = &g.nodes[id];
            let entry = op_impl(&node.op);
            let t0 = Instant::now();
            let mut sim_seconds = 0.0;
            let mut stats = None;

            let out = if entry.is_input() {
                input.expect("input nodes live in the first pipeline stage").clone()
            } else if node.placement == Placement::Vta {
                let key = keys[id].as_ref().expect("plan key precomputed for VTA node");
                let inputs: Vec<&Tensor<i8>> =
                    node.inputs.iter().map(|&i| values[i].as_ref().unwrap()).collect();
                let (out, s) = ex.exec_vta_node(g, id, key, schedules[id], &inputs)?;
                sim_seconds = s.total_cycles as f64 / clock_hz;
                stats = Some(s);
                out
            } else {
                exec_cpu_node(ex.cpu_mut(), g, id, &values)?
            };

            reports[id] = Some(NodeReport {
                name: node.name.clone(),
                kind: node.op.kind(),
                placement: node.placement,
                wall: t0.elapsed(),
                sim_seconds,
                stats,
                ops: node.op.ops(&node.shape),
            });
            values[id] = Some(out);
        }
    }

    Ok((values, reports))
}
