//! The serving layer: a JIT **compiled-plan cache** plus a
//! **pipelined, batched** front-end over the heterogeneous executor.
//!
//! The paper's runtime hides memory latency behind compute with
//! explicit task-level pipeline parallelism (§2.3) and reuses JIT'd
//! micro-kernels through a DRAM-resident cache (§3.2). This module
//! lifts both ideas from single-kernel to whole-graph granularity —
//! for **every operator in the registry**, not just conv2d:
//!
//! * [`PlanCache`] — an LRU cache of [`CompiledNode`]s keyed by
//!   ([`VtaConfig`] fingerprint, virtual threads, operator kind,
//!   operator fingerprint). The fingerprint comes from the node's
//!   [`VtaOp`](crate::compiler::VtaOp) implementation and covers the
//!   operator parameters, output shape, and baked-in constants
//!   (weights). Lowering a VTA node happens **once** per key; every
//!   later inference replays the sealed streams. Hit/miss/eviction
//!   counters mirror the micro-op cache's (ablation A2).
//! * [`ServingEngine`] — walks the partitioned graph in topological
//!   stages and serves single requests ([`ServingEngine::run_one`]) or
//!   batches ([`ServingEngine::run_batch`]), reporting **both** the
//!   naive-serial end-to-end time (every node back-to-back, the
//!   [`super::Executor`] discipline) and the **pipelined** time under
//!   a two-resource overlap model: CPU wall time of one request
//!   overlaps simulated VTA time of another, double-buffered (at most
//!   two requests in flight — the graph-level analogue of the two SRAM
//!   contexts in §4.3's virtual threading).
//!
//! Per-node durations are *measured* (host wall for CPU nodes and
//! orchestration, simulated cycles ÷ clock for VTA nodes); the
//! pipelined schedule then replays those durations against resource
//! and dependence constraints, exactly like the simulator replays
//! dependence tokens against its module timelines.

use super::executor::{exec_cpu_node, lift_compile_err, CpuBackend, ExecError, NodeReport};
use crate::arch::VtaConfig;
use crate::compiler::op::{execute_compiled, op_impl};
use crate::compiler::{CompiledNode, ScheduleChoice};
use crate::dse::records::TuningRecords;
use crate::graph::{stages, Graph, Node, Placement};
use crate::runtime::VtaRuntime;
use crate::util::Tensor;
use std::collections::HashMap;
use std::time::{Duration, Instant};

// Fingerprint helpers live with the operator registry; re-exported
// here for API continuity (and python/compile/synth.py parity).
pub use crate::compiler::op::{config_fingerprint, fnv1a64, weights_fingerprint};

// ---------------------------------------------------------------------
// Cache keys.
// ---------------------------------------------------------------------

/// Key of one compiled plan: everything the lowered artifact depends
/// on. Two graph nodes with identical params *and* identical constants
/// legitimately share a plan; identical params with different weights
/// do not (the weight image is DRAM-resident inside the plan).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Hardware variant fingerprint ([`config_fingerprint`]).
    pub config_fp: u64,
    /// Virtual-thread count the plan was lowered with.
    pub virtual_threads: usize,
    /// Operator kind (the registry key).
    pub kind: &'static str,
    /// Operator fingerprint
    /// ([`VtaOp::fingerprint`](crate::compiler::VtaOp::fingerprint)):
    /// shape parameters + output shape + baked constants.
    pub op_fp: u64,
}

// ---------------------------------------------------------------------
// Plan cache.
// ---------------------------------------------------------------------

/// Cumulative plan-cache counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Lookups served by an already-compiled plan.
    pub hits: u64,
    /// Lookups that had to compile.
    pub misses: u64,
    /// Plans evicted (LRU) to make room.
    pub evictions: u64,
}

struct CacheEntry {
    node: CompiledNode,
    last_use: u64,
}

/// LRU cache of compiled plans — the §3.2 micro-kernel cache, extended
/// to whole-node plans (instruction streams + packed constants + DRAM
/// residency) of any registered operator.
pub struct PlanCache {
    capacity: usize,
    entries: HashMap<PlanKey, CacheEntry>,
    clock: u64,
    stats: PlanCacheStats,
}

impl PlanCache {
    /// A cache holding at most `capacity` compiled plans.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "plan cache needs at least one slot");
        PlanCache { capacity, entries: HashMap::new(), clock: 0, stats: PlanCacheStats::default() }
    }

    /// Cumulative counters.
    pub fn stats(&self) -> PlanCacheStats {
        self.stats
    }

    /// Number of resident plans.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no plans are resident.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Maximum resident plans.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// True when `key` is resident (does not touch LRU state).
    pub fn contains(&self, key: &PlanKey) -> bool {
        self.entries.contains_key(key)
    }

    /// The resident plan for `key`, if any (does not touch LRU state;
    /// tests / introspection).
    pub fn peek(&self, key: &PlanKey) -> Option<&CompiledNode> {
        self.entries.get(key).map(|e| &e.node)
    }

    /// Resident plans per operator kind (reporting / tests).
    pub fn kinds(&self) -> HashMap<&'static str, usize> {
        let mut m = HashMap::new();
        for key in self.entries.keys() {
            *m.entry(key.kind).or_insert(0) += 1;
        }
        m
    }

    /// Total DRAM bytes held by resident plans.
    pub fn dram_bytes(&self) -> usize {
        self.entries.values().map(|e| e.node.dram_bytes()).sum()
    }

    /// Look up `key`, compiling (and inserting) on a miss. Evicts
    /// least-recently-used plans — releasing their DRAM residency —
    /// when the cache is full.
    pub fn get_or_compile<F>(
        &mut self,
        rt: &mut VtaRuntime,
        key: &PlanKey,
        compile: F,
    ) -> Result<&CompiledNode, ExecError>
    where
        F: FnOnce(&mut VtaRuntime) -> Result<CompiledNode, ExecError>,
    {
        self.clock += 1;
        let clock = self.clock;
        if self.entries.contains_key(key) {
            self.stats.hits += 1;
            let e = self.entries.get_mut(key).unwrap();
            e.last_use = clock;
            return Ok(&self.entries[key].node);
        }
        self.stats.misses += 1;
        while self.entries.len() >= self.capacity {
            let victim =
                self.entries.iter().min_by_key(|(_, e)| e.last_use).map(|(k, _)| k.clone());
            let Some(vk) = victim else { break };
            let entry = self.entries.remove(&vk).expect("victim key resident");
            entry.node.free(rt).map_err(ExecError::PlanCache)?;
            self.stats.evictions += 1;
        }
        let node = compile(rt)?;
        self.entries.insert(key.clone(), CacheEntry { node, last_use: clock });
        Ok(&self.entries[key].node)
    }

    /// Drop every resident plan, releasing its DRAM.
    pub fn flush(&mut self, rt: &mut VtaRuntime) -> Result<(), ExecError> {
        for (_, entry) in self.entries.drain() {
            entry.node.free(rt).map_err(ExecError::PlanCache)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Pipelined timing model.
// ---------------------------------------------------------------------

/// Result of replaying measured node durations against the
/// two-resource (CPU / VTA) pipelined schedule.
#[derive(Clone, Debug)]
pub struct PipelineModel {
    /// End-to-end time of the whole batch under the pipelined,
    /// double-buffered schedule.
    pub makespan_seconds: f64,
    /// Per-request completion times (all requests arrive at t = 0).
    pub completion_seconds: Vec<f64>,
    /// End-to-end time of the naive serial discipline: every node of
    /// every request back-to-back.
    pub serial_seconds: f64,
}

/// Replay per-node durations against dependence + resource
/// constraints.
///
/// Model: two resources — the CPU (measured wall time) and the VTA
/// (simulated cycles ÷ clock). Within a request, a node starts when
/// its inputs are done *and* its resource is free; across requests,
/// double buffering admits request `r` once request `r - 2` has
/// completed (two requests in flight, mirroring the two SRAM contexts
/// of §4.3). Zero-duration nodes occupy nothing.
pub fn pipeline_schedule(g: &Graph, per_request: &[Vec<NodeReport>]) -> PipelineModel {
    let out_id = g.output().expect("non-empty graph");
    let mut cpu_free = 0.0f64;
    let mut vta_free = 0.0f64;
    let mut completion: Vec<f64> = Vec::with_capacity(per_request.len());
    let mut serial = 0.0f64;
    let mut makespan = 0.0f64;

    for (r, reports) in per_request.iter().enumerate() {
        debug_assert_eq!(reports.len(), g.nodes.len());
        let arrival = if r >= 2 { completion[r - 2] } else { 0.0 };
        let mut finish = vec![0.0f64; g.nodes.len()];
        for node in &g.nodes {
            let nr = &reports[node.id];
            let dur = nr.wall.as_secs_f64() + nr.sim_seconds;
            serial += dur;
            let ready = node.inputs.iter().map(|&i| finish[i]).fold(arrival, f64::max);
            let start = if node.placement == Placement::Vta {
                let s = ready.max(vta_free);
                vta_free = s + dur;
                s
            } else if dur > 0.0 {
                let s = ready.max(cpu_free);
                cpu_free = s + dur;
                s
            } else {
                ready
            };
            finish[node.id] = start + dur;
        }
        let done = finish[out_id];
        completion.push(done);
        makespan = makespan.max(done);
    }
    PipelineModel { makespan_seconds: makespan, completion_seconds: completion, serial_seconds: serial }
}

// ---------------------------------------------------------------------
// Serving engine.
// ---------------------------------------------------------------------

/// Report for one served request.
#[derive(Debug)]
pub struct ServeReport {
    /// Final output tensor.
    pub output: Tensor<i8>,
    /// Per-node records, indexed by node id.
    pub nodes: Vec<NodeReport>,
    /// Naive serial end-to-end model time (sum of all node durations).
    pub serial_seconds: f64,
    /// Pipelined model time for this single request (intra-request
    /// overlap only).
    pub pipelined_seconds: f64,
}

/// Report for a served batch.
#[derive(Debug)]
pub struct BatchReport {
    /// Per-request outputs, in request order.
    pub outputs: Vec<Tensor<i8>>,
    /// Per-request, per-node records.
    pub per_request: Vec<Vec<NodeReport>>,
    /// Naive serial end-to-end model time of the whole batch.
    pub serial_seconds: f64,
    /// Pipelined, double-buffered end-to-end model time of the batch.
    pub pipelined_seconds: f64,
    /// Per-request completion times under the pipelined schedule.
    pub completion_seconds: Vec<f64>,
    /// Plan-cache counters *for this batch* (end minus start).
    pub cache: PlanCacheStats,
    /// Real host wall time of serving the batch (includes compiles on
    /// cold caches).
    pub host_wall: Duration,
}

impl BatchReport {
    /// Requests per modeled second under the pipelined schedule.
    pub fn throughput(&self) -> f64 {
        if self.pipelined_seconds > 0.0 {
            self.outputs.len() as f64 / self.pipelined_seconds
        } else {
            0.0
        }
    }

    /// Serial ÷ pipelined model time.
    pub fn speedup(&self) -> f64 {
        if self.pipelined_seconds > 0.0 {
            self.serial_seconds / self.pipelined_seconds
        } else {
            1.0
        }
    }

    /// Latency percentile (`q` in [0, 1]) over per-request completion
    /// times (all requests arrive at t = 0).
    pub fn latency_percentile(&self, q: f64) -> f64 {
        if self.completion_seconds.is_empty() {
            return 0.0;
        }
        let mut sorted = self.completion_seconds.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len()) - 1;
        sorted[idx]
    }
}

/// The batched, plan-caching serving engine.
pub struct ServingEngine {
    rt: VtaRuntime,
    cpu: CpuBackend,
    cache: PlanCache,
    virtual_threads: usize,
    config_fp: u64,
    /// Tuned schedules from `vta dse`, consulted at compile time. Fixed
    /// for the engine's lifetime, so [`PlanKey`] does not need to carry
    /// a schedule fingerprint — within one engine, (config, vt, op)
    /// still uniquely determines the compiled artifact.
    records: TuningRecords,
}

impl ServingEngine {
    /// Build an engine over a fresh runtime with `dram_size` bytes of
    /// device DRAM (compiled plans hold their buffers resident there),
    /// a CPU backend, `virtual_threads` ∈ {1, 2}, and a plan cache of
    /// `cache_capacity` entries.
    pub fn new(
        cfg: &VtaConfig,
        dram_size: usize,
        cpu: CpuBackend,
        virtual_threads: usize,
        cache_capacity: usize,
    ) -> Self {
        Self::with_records(cfg, dram_size, cpu, virtual_threads, cache_capacity, TuningRecords::new())
    }

    /// Like [`Self::new`], seeded with a tuning-record store (usually
    /// loaded from the JSON file `vta dse` persisted): every VTA node
    /// whose (config, operator) pair has a record compiles with the
    /// tuned schedule instead of the planner's greedy default, so
    /// tuned schedules survive restarts and serving traffic
    /// automatically runs the tuned plan.
    pub fn with_records(
        cfg: &VtaConfig,
        dram_size: usize,
        cpu: CpuBackend,
        virtual_threads: usize,
        cache_capacity: usize,
        records: TuningRecords,
    ) -> Self {
        assert!(
            virtual_threads == 1 || virtual_threads == 2,
            "1 or 2 virtual threads"
        );
        ServingEngine {
            rt: VtaRuntime::new(cfg, dram_size),
            cpu,
            cache: PlanCache::new(cache_capacity),
            virtual_threads,
            config_fp: config_fingerprint(cfg),
            records,
        }
    }

    /// Number of tuning records the engine consults.
    pub fn tuned_records(&self) -> usize {
        self.records.len()
    }

    /// The tuned schedule the engine would apply to `node`, if its
    /// record store has one for this (config, operator) pair.
    pub fn tuned_schedule(&self, node: &Node) -> Option<ScheduleChoice> {
        let entry = op_impl(&node.op);
        self.records.lookup(self.config_fp, self.virtual_threads, entry.schedule_fingerprint(node))
    }

    /// The schedule baked into the resident compiled plan for `key`
    /// (`None` = no resident plan, or the plan uses the default
    /// schedule). Tests / introspection.
    pub fn cached_schedule(&self, key: &PlanKey) -> Option<ScheduleChoice> {
        self.cache.peek(key).and_then(|node| node.schedule)
    }

    /// Cumulative plan-cache counters.
    pub fn cache_stats(&self) -> PlanCacheStats {
        self.cache.stats()
    }

    /// Number of resident compiled plans.
    pub fn cached_plans(&self) -> usize {
        self.cache.len()
    }

    /// Resident plans per operator kind.
    pub fn cached_kinds(&self) -> HashMap<&'static str, usize> {
        self.cache.kinds()
    }

    /// DRAM bytes held by resident plans.
    pub fn cache_dram_bytes(&self) -> usize {
        self.cache.dram_bytes()
    }

    /// The plan key the engine would use for `node` (any registered
    /// operator; tests / introspection).
    pub fn plan_key(&self, g: &Graph, node: &Node) -> PlanKey {
        let entry = op_impl(&node.op);
        PlanKey {
            config_fp: self.config_fp,
            virtual_threads: self.virtual_threads,
            kind: entry.kind(),
            op_fp: entry.fingerprint(g, node),
        }
    }

    /// Precompute the plan key of every VTA-resident node (operator
    /// fingerprints hash the full weight image — do it once per graph,
    /// not once per request).
    fn plan_keys(&self, g: &Graph) -> Vec<Option<PlanKey>> {
        g.nodes
            .iter()
            .map(|node| (node.placement == Placement::Vta).then(|| self.plan_key(g, node)))
            .collect()
    }

    /// Precompute the tuned schedule of every VTA-resident node (the
    /// record lookup hashes the operator's debug form — once per
    /// graph, like the plan keys, not once per request).
    fn tuned_schedules(&self, g: &Graph) -> Vec<Option<ScheduleChoice>> {
        if self.records.is_empty() {
            return vec![None; g.nodes.len()];
        }
        g.nodes
            .iter()
            .map(|node| {
                if node.placement == Placement::Vta {
                    self.tuned_schedule(node)
                } else {
                    None
                }
            })
            .collect()
    }

    /// Serve one request.
    pub fn run_one(&mut self, g: &Graph, input: &Tensor<i8>) -> Result<ServeReport, ExecError> {
        let stage_order = stages(g);
        let keys = self.plan_keys(g);
        let schedules = self.tuned_schedules(g);
        let (output, nodes) = self.run_graph(g, input, &stage_order, &keys, &schedules)?;
        let model = pipeline_schedule(g, std::slice::from_ref(&nodes));
        Ok(ServeReport {
            output,
            nodes,
            serial_seconds: model.serial_seconds,
            pipelined_seconds: model.makespan_seconds,
        })
    }

    /// Serve a batch of requests, amortizing stage computation, plan
    /// keys (weight fingerprints), plan lookup, and constant packing
    /// across the batch. Outputs are bit-identical to serving each
    /// request alone (and to the serial [`super::Executor`]).
    pub fn run_batch(
        &mut self,
        g: &Graph,
        inputs: &[Tensor<i8>],
    ) -> Result<BatchReport, ExecError> {
        let stats0 = self.cache.stats();
        let t0 = Instant::now();
        let stage_order = stages(g);
        let keys = self.plan_keys(g);
        let schedules = self.tuned_schedules(g);
        let mut outputs = Vec::with_capacity(inputs.len());
        let mut per_request = Vec::with_capacity(inputs.len());
        for input in inputs {
            let (out, nodes) = self.run_graph(g, input, &stage_order, &keys, &schedules)?;
            outputs.push(out);
            per_request.push(nodes);
        }
        let host_wall = t0.elapsed();
        let model = pipeline_schedule(g, &per_request);
        let s1 = self.cache.stats();
        Ok(BatchReport {
            outputs,
            per_request,
            serial_seconds: model.serial_seconds,
            pipelined_seconds: model.makespan_seconds,
            completion_seconds: model.completion_seconds,
            cache: PlanCacheStats {
                hits: s1.hits - stats0.hits,
                misses: s1.misses - stats0.misses,
                evictions: s1.evictions - stats0.evictions,
            },
            host_wall,
        })
    }

    /// Execute the graph once, in topological stages, through the plan
    /// cache. `stage_order` and `keys` come from [`crate::graph::stages`]
    /// and [`Self::plan_keys`] (precomputed so batches amortize them).
    /// Returns the output and per-node records indexed by node id.
    ///
    /// Dispatch is op-generic: every VTA node compiles and runs
    /// through its registered [`VtaOp`](crate::compiler::VtaOp)
    /// implementation.
    fn run_graph(
        &mut self,
        g: &Graph,
        input: &Tensor<i8>,
        stage_order: &[Vec<usize>],
        keys: &[Option<PlanKey>],
        schedules: &[Option<ScheduleChoice>],
    ) -> Result<(Tensor<i8>, Vec<NodeReport>), ExecError> {
        let clock_hz = self.rt.ctx.config().clock_hz;
        let mut values: Vec<Option<Tensor<i8>>> = vec![None; g.nodes.len()];
        let mut reports: Vec<Option<NodeReport>> = (0..g.nodes.len()).map(|_| None).collect();

        for stage in stage_order {
            for &id in stage {
                let node = &g.nodes[id];
                let entry = op_impl(&node.op);
                let t0 = Instant::now();
                let mut sim_seconds = 0.0;
                let mut stats = None;

                let out = if entry.is_input() {
                    input.clone()
                } else if node.placement == Placement::Vta {
                    let inputs: Vec<&Tensor<i8>> =
                        node.inputs.iter().map(|&i| values[i].as_ref().unwrap()).collect();
                    let key = keys[id].as_ref().expect("plan key precomputed for VTA node");
                    let vt = self.virtual_threads;
                    // Best-known schedule from the DSE record store
                    // (None = the planner's greedy default),
                    // precomputed per graph.
                    let schedule = schedules[id];
                    // Split borrows: the cache hands out a plan while
                    // the runtime executes it.
                    let rt = &mut self.rt;
                    let compiled = self.cache.get_or_compile(rt, key, |rt| {
                        entry
                            .compile(rt, g, node, vt, schedule.as_ref())
                            .map_err(|e| lift_compile_err(&node.name, e))
                    })?;
                    let (out, s) = execute_compiled(entry, compiled, rt, &inputs)
                        .map_err(|e| lift_compile_err(&node.name, e))?;
                    sim_seconds = s.total_cycles as f64 / clock_hz;
                    stats = Some(s);
                    out
                } else {
                    exec_cpu_node(&mut self.cpu, g, id, &values)?
                };

                reports[id] = Some(NodeReport {
                    name: node.name.clone(),
                    kind: node.op.kind(),
                    placement: node.placement,
                    wall: t0.elapsed(),
                    sim_seconds,
                    stats,
                    ops: node.op.ops(&node.shape),
                });
                values[id] = Some(out);
            }
        }

        let out_id = g.output().expect("non-empty graph");
        Ok((
            values[out_id].take().unwrap(),
            reports.into_iter().map(|r| r.expect("stages cover every node")).collect(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{Conv2dParams, MatmulParams, Requant};
    use crate::exec::Executor;
    use crate::graph::{partition, Op, PartitionPolicy};
    use crate::util::XorShiftRng;

    fn rand_t(seed: u64, shape: &[usize]) -> Tensor<i8> {
        let mut rng = XorShiftRng::new(seed);
        Tensor::from_vec(shape, rng.vec_i8(shape.iter().product(), -8, 8)).unwrap()
    }

    fn conv_p(ic: usize, oc: usize, relu: bool) -> Conv2dParams {
        Conv2dParams {
            h: 8,
            w: 8,
            ic,
            oc,
            k: 3,
            s: 1,
            requant: crate::compiler::Requant { shift: 6, relu },
        }
    }

    /// Two VTA convs with identical params but different weights →
    /// distinct plans. A batch of three requests compiles each exactly
    /// once and hits on every later lookup.
    fn two_conv_graph() -> Graph {
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
        let c1 = g.add("c1", Op::Conv2d { p: conv_p(16, 16, true) }, &[x]).unwrap();
        g.set_weights(c1, rand_t(101, &[16, 16, 3, 3]));
        let c2 = g.add("c2", Op::Conv2d { p: conv_p(16, 16, false) }, &[c1]).unwrap();
        g.set_weights(c2, rand_t(102, &[16, 16, 3, 3]));
        let _p = g.add("pool", Op::MaxPool { k: 2, s: 2, pad: 0 }, &[c2]).unwrap();
        g
    }

    /// A small ResNet basic block: conv → conv, residual add, relu.
    fn residual_block_graph() -> Graph {
        let p = conv_p(16, 16, false);
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
        let c1 = g.add("c1", Op::Conv2d { p }, &[x]).unwrap();
        g.set_weights(c1, rand_t(111, &[16, 16, 3, 3]));
        let c2 = g.add("c2", Op::Conv2d { p }, &[c1]).unwrap();
        g.set_weights(c2, rand_t(112, &[16, 16, 3, 3]));
        let add = g.add("add", Op::Add, &[c2, x]).unwrap();
        let _r = g.add("relu", Op::Relu, &[add]).unwrap();
        g
    }

    /// A ResNet-style tail with every registered VTA op class: conv,
    /// residual add, standalone relu, gap, dense classifier.
    fn mixed_op_graph() -> Graph {
        let p = conv_p(16, 16, false);
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
        let c1 = g.add("c1", Op::Conv2d { p: conv_p(16, 16, true) }, &[x]).unwrap();
        g.set_weights(c1, rand_t(121, &[16, 16, 3, 3]));
        let c2 = g.add("c2", Op::Conv2d { p }, &[c1]).unwrap();
        g.set_weights(c2, rand_t(122, &[16, 16, 3, 3]));
        let add = g.add("add", Op::Add, &[c2, x]).unwrap();
        let r = g.add("relu", Op::Relu, &[add]).unwrap();
        let gap = g.add("gap", Op::GlobalAvgPool, &[r]).unwrap();
        let fcp = MatmulParams { m: 1, k: 16, n: 10, requant: Requant { shift: 2, relu: false } };
        let fc = g.add("fc", Op::Dense { p: fcp }, &[gap]).unwrap();
        g.set_weights(fc, rand_t(123, &[10, 16]));
        g
    }

    fn engine(cap: usize) -> ServingEngine {
        ServingEngine::new(&VtaConfig::pynq(), 64 << 20, CpuBackend::Native, 2, cap)
    }

    #[test]
    fn plan_cache_counts_hits_and_misses() {
        let cfg = VtaConfig::pynq();
        let mut g = two_conv_graph();
        partition(&mut g, &PartitionPolicy::paper(&cfg));

        let mut eng = engine(8);
        let inputs: Vec<_> = (0..3).map(|i| rand_t(200 + i, &[1, 16, 8, 8])).collect();
        let batch = eng.run_batch(&g, &inputs).unwrap();

        // Lowering ran once per unique VTA node, despite 3 requests x
        // 2 conv nodes = 6 lookups.
        assert_eq!(batch.cache.misses, 2, "one compile per unique (params, weights)");
        assert_eq!(batch.cache.hits, 4, "every later lookup hits");
        assert_eq!(batch.cache.evictions, 0);
        assert_eq!(eng.cached_plans(), 2);

        // A second (warm) batch never compiles.
        let warm = eng.run_batch(&g, &inputs).unwrap();
        assert_eq!(warm.cache.misses, 0);
        assert_eq!(warm.cache.hits, 6);
    }

    #[test]
    fn plan_cache_evicts_lru_and_stays_correct() {
        let cfg = VtaConfig::pynq();
        let mut g = two_conv_graph();
        partition(&mut g, &PartitionPolicy::paper(&cfg));
        let input = rand_t(300, &[1, 16, 8, 8]);

        // Reference output from the serial executor.
        let mut ex = Executor::new(VtaRuntime::new(&cfg, 64 << 20), CpuBackend::Native);
        let expect = ex.run(&g, &input).unwrap().output;

        // Capacity 1: the two conv plans thrash, evicting each other.
        let mut eng = engine(1);
        let r1 = eng.run_one(&g, &input).unwrap();
        let r2 = eng.run_one(&g, &input).unwrap();
        assert_eq!(r1.output, expect);
        assert_eq!(r2.output, expect, "eviction must not corrupt results");
        let s = eng.cache_stats();
        assert_eq!(s.hits, 0, "capacity 1 cannot retain either plan");
        assert_eq!(s.misses, 4);
        assert!(s.evictions >= 3, "thrashing must evict: {s:?}");
        assert_eq!(eng.cached_plans(), 1);
    }

    #[test]
    fn eviction_releases_dram() {
        let cfg = VtaConfig::pynq();
        let mut g = two_conv_graph();
        partition(&mut g, &PartitionPolicy::paper(&cfg));
        let input = rand_t(310, &[1, 16, 8, 8]);

        let mut eng = engine(1);
        eng.run_one(&g, &input).unwrap();
        let one_plan = eng.cache_dram_bytes();
        eng.run_one(&g, &input).unwrap();
        // Still exactly one resident plan's worth of DRAM (same shapes
        // → same footprint), not an accumulating leak.
        assert_eq!(eng.cache_dram_bytes(), one_plan);
    }

    #[test]
    fn plan_keys_isolate_configs_weights_and_kinds() {
        // Two single-conv graphs with identical params but different
        // weights, plus a residual block for the ALU-op kinds.
        let build = |wseed: u64| {
            let mut g = Graph::new();
            let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
            let c = g.add("c", Op::Conv2d { p: conv_p(16, 16, false) }, &[x]).unwrap();
            g.set_weights(c, rand_t(wseed, &[16, 16, 3, 3]));
            g
        };
        let g1 = build(400);
        let g2 = build(401);

        let pynq = engine(4);
        let mut wide_cfg = VtaConfig::pynq();
        wide_cfg.uop_buf_bytes *= 2;
        let wide = ServingEngine::new(&wide_cfg, 64 << 20, CpuBackend::Native, 2, 4);

        // Same op + weights under different hardware variants → keys
        // differ (a plan compiled for one variant is never replayed on
        // another).
        assert_ne!(pynq.plan_key(&g1, &g1.nodes[1]), wide.plan_key(&g1, &g1.nodes[1]));
        // Same config + op, different weights → keys differ (weights
        // are baked into the plan's DRAM image).
        assert_ne!(pynq.plan_key(&g1, &g1.nodes[1]), pynq.plan_key(&g2, &g2.nodes[1]));
        // Identical everything → same key (sharing is intended).
        assert_eq!(pynq.plan_key(&g1, &g1.nodes[1]), pynq.plan_key(&g1, &g1.nodes[1]));

        // Different op kinds over the same shape → different keys.
        let rb = residual_block_graph();
        let add = rb.nodes.iter().find(|n| n.op.kind() == "add").unwrap();
        let relu = rb.nodes.iter().find(|n| n.op.kind() == "relu").unwrap();
        let ka = pynq.plan_key(&rb, add);
        let kr = pynq.plan_key(&rb, relu);
        assert_ne!(ka, kr);
        assert_eq!(ka.kind, "add");
        assert_eq!(kr.kind, "relu");
    }

    /// Batched serving produces exactly the serial executor's outputs
    /// on a ResNet basic block — per request, bit-identical.
    #[test]
    fn batched_matches_sequential_executor_on_residual_block() {
        let cfg = VtaConfig::pynq();
        let mut g = residual_block_graph();
        partition(&mut g, &PartitionPolicy::paper(&cfg));
        let inputs: Vec<_> = (0..3).map(|i| rand_t(500 + i, &[1, 16, 8, 8])).collect();

        let mut eng = engine(8);
        let batch = eng.run_batch(&g, &inputs).unwrap();

        for (i, input) in inputs.iter().enumerate() {
            let mut ex = Executor::new(VtaRuntime::new(&cfg, 64 << 20), CpuBackend::Native);
            let expect = ex.run(&g, input).unwrap().output;
            assert_eq!(batch.outputs[i], expect, "request {i} diverged from serial executor");
        }

        // The pipelined model can only help, and with both CPU and VTA
        // work in flight across 3 requests it must strictly help
        // (guarded on the CPU side having measurable duration, so a
        // pathological zero-resolution clock can't flake the test).
        assert!(batch.pipelined_seconds <= batch.serial_seconds + 1e-12);
        let cpu_seconds: f64 = batch
            .per_request
            .iter()
            .flatten()
            .filter(|n| n.placement != Placement::Vta)
            .map(|n| n.wall.as_secs_f64())
            .sum();
        if cpu_seconds > 0.0 {
            assert!(
                batch.pipelined_seconds < batch.serial_seconds,
                "no overlap found: pipelined {} vs serial {}",
                batch.pipelined_seconds,
                batch.serial_seconds
            );
        }
        assert!(batch.throughput() > 0.0);
        assert!(batch.latency_percentile(0.99) >= batch.latency_percentile(0.50));
    }

    /// Op-generic caching: a graph with conv, add, relu, and dense all
    /// offloaded compiles each unique node exactly once and reuses
    /// every plan across the batch — the acceptance scenario of the
    /// operator-registry redesign.
    #[test]
    fn mixed_op_kinds_cache_and_match_serial_executor() {
        let cfg = VtaConfig::pynq();
        let mut g = mixed_op_graph();
        let policy = PartitionPolicy::offload_all(&cfg);
        let (vta_nodes, _) = partition(&mut g, &policy);
        assert_eq!(vta_nodes, 5, "conv x2 + add + relu + dense offload");

        let inputs: Vec<_> = (0..3).map(|i| rand_t(600 + i, &[1, 16, 8, 8])).collect();
        let mut eng = engine(16);
        let batch = eng.run_batch(&g, &inputs).unwrap();

        // One compile per unique VTA node; every later lookup hits.
        assert_eq!(batch.cache.misses, 5);
        assert_eq!(batch.cache.hits, 10);
        let kinds = eng.cached_kinds();
        assert_eq!(kinds.get("conv2d"), Some(&2));
        assert_eq!(kinds.get("add"), Some(&1));
        assert_eq!(kinds.get("relu"), Some(&1));
        assert_eq!(kinds.get("dense"), Some(&1));

        // Bit-identical to the serial executor (which itself verifies
        // against the CPU-only reference in the exec tests).
        for (i, input) in inputs.iter().enumerate() {
            let mut ex = Executor::new(VtaRuntime::new(&cfg, 64 << 20), CpuBackend::Native);
            let expect = ex.run(&g, input).unwrap().output;
            assert_eq!(batch.outputs[i], expect, "request {i} diverged");
        }

        // Warm batch: pure replay across every op kind.
        let warm = eng.run_batch(&g, &inputs).unwrap();
        assert_eq!(warm.cache.misses, 0);
        assert_eq!(warm.cache.hits, 15);
    }

    /// Eviction works across mixed op kinds: a cache smaller than the
    /// working set thrashes but stays correct.
    #[test]
    fn mixed_op_kinds_evict_and_stay_correct() {
        let cfg = VtaConfig::pynq();
        let mut g = mixed_op_graph();
        partition(&mut g, &PartitionPolicy::offload_all(&cfg));
        let input = rand_t(700, &[1, 16, 8, 8]);

        let mut ex = Executor::new(VtaRuntime::new(&cfg, 64 << 20), CpuBackend::Native);
        let expect = ex.run(&g, &input).unwrap().output;

        let mut eng = engine(2);
        let r1 = eng.run_one(&g, &input).unwrap();
        let r2 = eng.run_one(&g, &input).unwrap();
        assert_eq!(r1.output, expect);
        assert_eq!(r2.output, expect, "eviction must not corrupt mixed-kind results");
        let s = eng.cache_stats();
        assert_eq!(s.misses, 10, "5 VTA nodes x 2 requests all miss at capacity 2");
        assert!(s.evictions >= 8, "thrashing must evict: {s:?}");
        assert!(eng.cached_plans() <= 2);
    }

    /// The schedule respects dependences: no request finishes before
    /// the sum of its critical-path durations, and completions are
    /// bounded by the makespan.
    #[test]
    fn pipeline_schedule_is_sane() {
        let cfg = VtaConfig::pynq();
        let mut g = residual_block_graph();
        partition(&mut g, &PartitionPolicy::paper(&cfg));
        let inputs: Vec<_> = (0..4).map(|i| rand_t(600 + i, &[1, 16, 8, 8])).collect();

        let mut eng = engine(8);
        let batch = eng.run_batch(&g, &inputs).unwrap();
        let model = pipeline_schedule(&g, &batch.per_request);

        assert_eq!(model.completion_seconds.len(), 4);
        for (r, &c) in model.completion_seconds.iter().enumerate() {
            assert!(c <= model.makespan_seconds + 1e-12);
            // Completions are at least the request's own chain time on
            // the critical path (here: the whole graph is one chain
            // except the shortcut).
            let own: f64 = batch.per_request[r]
                .iter()
                .map(|n| n.wall.as_secs_f64() + n.sim_seconds)
                .sum();
            assert!(c <= model.serial_seconds + 1e-12);
            assert!(own > 0.0);
        }
        // Makespan is monotone in batch size: a prefix of requests
        // cannot take longer than the full batch.
        let prefix = pipeline_schedule(&g, &batch.per_request[..2]);
        assert!(prefix.makespan_seconds <= model.makespan_seconds + 1e-12);
    }
}
