//! XLA/PJRT runtime wrapper: loads `artifacts/*.hlo.txt` (HLO **text**,
//! the interchange format the build path emits — see
//! `python/compile/aot.py`) and executes them on the PJRT CPU client.
//!
//! This is the "CPU side" of the heterogeneous executor: the JAX model
//! is lowered once at build time; at run time Rust feeds int8 tensors
//! straight into the compiled executables. Python never runs here.
//!
//! The real backend needs the external `xla` crate (and its C++
//! runtime), which the offline build environment does not provide, so
//! it sits behind the `pjrt` cargo feature. The default build gets a
//! stub with the same API whose `has()` always answers `false` — the
//! executor then falls back to the native Rust kernels, and
//! `cargo test` stays green with no artifact or toolchain dependency.
//!
//! NOTE: enabling `pjrt` requires *also* adding `xla` to
//! `[dependencies]` in `Cargo.toml` — the crate is intentionally not
//! declared there (even optionally) so that offline dependency
//! resolution never touches it. See the `[features]` comment in
//! `Cargo.toml`.

#[cfg(feature = "pjrt")]
mod real {
    use crate::util::Tensor;
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use thiserror::Error;

    /// PJRT path errors.
    #[derive(Debug, Error)]
    pub enum PjrtError {
        #[error("artifact {0} not found (run `make artifacts` first)")]
        MissingArtifact(PathBuf),
        #[error("xla error: {0}")]
        Xla(#[from] xla::Error),
        #[error("artifact {name}: expected {expected} outputs, got {got}")]
        BadArity { name: String, expected: usize, got: usize },
    }

    /// A cache of compiled PJRT executables keyed by artifact name.
    pub struct PjrtCache {
        client: xla::PjRtClient,
        dir: PathBuf,
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl PjrtCache {
        /// Create a CPU PJRT client over an artifact directory.
        pub fn new(dir: impl AsRef<Path>) -> Result<Self, PjrtError> {
            Ok(PjrtCache {
                client: xla::PjRtClient::cpu()?,
                dir: dir.as_ref().to_path_buf(),
                exes: HashMap::new(),
            })
        }

        /// The artifact directory.
        pub fn dir(&self) -> &Path {
            &self.dir
        }

        /// True when the named artifact file exists.
        pub fn has(&self, name: &str) -> bool {
            self.dir.join(format!("{name}.hlo.txt")).exists()
        }

        /// Load (compile-once) an artifact by name (`name`.hlo.txt).
        pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable, PjrtError> {
            if !self.exes.contains_key(name) {
                let path = self.dir.join(format!("{name}.hlo.txt"));
                if !path.exists() {
                    return Err(PjrtError::MissingArtifact(path));
                }
                let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self.client.compile(&comp)?;
                self.exes.insert(name.to_string(), exe);
            }
            Ok(&self.exes[name])
        }

        /// Execute an artifact on int8 tensors, returning int8 tensors.
        ///
        /// All artifacts are lowered with `return_tuple=True`, so the
        /// result is a tuple literal; each element converts back to a
        /// [`Tensor<i8>`] with its shape read from the literal.
        pub fn run_i8(
            &mut self,
            name: &str,
            inputs: &[&Tensor<i8>],
        ) -> Result<Vec<Tensor<i8>>, PjrtError> {
            let parts = self.run_raw(name, inputs)?;
            let mut out = Vec::with_capacity(parts.len());
            for lit in parts {
                out.push(literal_to_tensor(&lit)?);
            }
            Ok(out)
        }

        /// Execute an artifact whose outputs are int32 (e.g. the raw
        /// Pallas GEMM accumulator).
        pub fn run_i32(
            &mut self,
            name: &str,
            inputs: &[&Tensor<i8>],
        ) -> Result<Vec<Tensor<i32>>, PjrtError> {
            let parts = self.run_raw(name, inputs)?;
            let mut out = Vec::with_capacity(parts.len());
            for lit in parts {
                let shape = lit.array_shape()?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit.to_vec::<i32>()?;
                out.push(Tensor::from_vec(&dims, data).expect("shape matches element count"));
            }
            Ok(out)
        }

        fn run_raw(
            &mut self,
            name: &str,
            inputs: &[&Tensor<i8>],
        ) -> Result<Vec<xla::Literal>, PjrtError> {
            let lits: Vec<xla::Literal> = inputs.iter().map(|t| tensor_to_literal(t)).collect();
            let exe = self.load(name)?;
            let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
            Ok(result.to_tuple()?)
        }
    }

    /// Convert a host int8 tensor to an XLA literal.
    fn tensor_to_literal(t: &Tensor<i8>) -> xla::Literal {
        let dims: Vec<usize> = t.shape().to_vec();
        let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::S8, &dims);
        lit.copy_raw_from(t.data()).expect("literal size matches tensor");
        lit
    }

    /// Convert an XLA int8 literal back to a host tensor.
    fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor<i8>, PjrtError> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<i8>()?;
        Ok(Tensor::from_vec(&dims, data).expect("literal element count matches shape"))
    }
}

#[cfg(feature = "pjrt")]
pub use real::{PjrtCache, PjrtError};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use crate::util::Tensor;
    use std::path::{Path, PathBuf};
    use thiserror::Error;

    /// PJRT path errors (stub build).
    #[derive(Debug, Error)]
    pub enum PjrtError {
        #[error("artifact {0} not found (run `make artifacts` first)")]
        MissingArtifact(PathBuf),
        #[error("built without the `pjrt` feature: artifact {0} cannot run (rebuild with `--features pjrt`)")]
        Disabled(String),
    }

    /// Stub executable cache: reports every artifact as absent, so the
    /// executor always takes its native fallback.
    pub struct PjrtCache {
        dir: PathBuf,
    }

    impl PjrtCache {
        /// Create a stub cache over an artifact directory.
        pub fn new(dir: impl AsRef<Path>) -> Result<Self, PjrtError> {
            Ok(PjrtCache { dir: dir.as_ref().to_path_buf() })
        }

        /// The artifact directory.
        pub fn dir(&self) -> &Path {
            &self.dir
        }

        /// Always `false` in the stub build.
        pub fn has(&self, _name: &str) -> bool {
            false
        }

        /// Always an error in the stub build.
        pub fn run_i8(
            &mut self,
            name: &str,
            _inputs: &[&Tensor<i8>],
        ) -> Result<Vec<Tensor<i8>>, PjrtError> {
            Err(PjrtError::Disabled(name.to_string()))
        }

        /// Always an error in the stub build.
        pub fn run_i32(
            &mut self,
            name: &str,
            _inputs: &[&Tensor<i8>],
        ) -> Result<Vec<Tensor<i32>>, PjrtError> {
            Err(PjrtError::Disabled(name.to_string()))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtCache, PjrtError};
