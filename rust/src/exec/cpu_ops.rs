//! Native CPU implementations of the operators the paper leaves on the
//! ARM core (§5): max pooling, global average pooling, the dense
//! classifier, residual adds, ReLU. All int8 with the same semantics
//! as the JAX model (`python/compile/model.py`).

use crate::compiler::plan::MatmulParams;
use crate::compiler::reference::matmul_ref;
use crate::graph::Graph;
use crate::util::Tensor;

/// Max pooling over NCHW int8. Out-of-bounds taps are skipped (taps
/// initialize at `i8::MIN`), matching the JAX model's `-inf`-padded
/// `reduce_window`.
pub fn maxpool_i8(x: &Tensor<i8>, k: usize, s: usize, pad: usize) -> Tensor<i8> {
    let [n, c, h, w] = [x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]];
    let oh = (h + 2 * pad - k) / s + 1;
    let ow = (w + 2 * pad - k) / s + 1;
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    let src = x.data();
    let dst = out.data_mut();
    for nn in 0..n {
        for cc in 0..c {
            let plane = (nn * c + cc) * h * w;
            for y in 0..oh {
                for xx in 0..ow {
                    let mut m = i8::MIN;
                    for ky in 0..k {
                        for kx in 0..k {
                            let iy = (y * s + ky) as isize - pad as isize;
                            let ix = (xx * s + kx) as isize - pad as isize;
                            if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                                m = m.max(src[plane + iy as usize * w + ix as usize]);
                            }
                        }
                    }
                    dst[((nn * c + cc) * oh + y) * ow + xx] = m;
                }
            }
        }
    }
    out
}

/// Global average pooling NCHW → [N, C], round-to-nearest-even-free
/// integer mean (truncating division, matching the JAX model).
pub fn global_avg_pool_i8(x: &Tensor<i8>) -> Tensor<i8> {
    let [n, c, h, w] = [x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]];
    let mut out = Tensor::zeros(&[n, c]);
    let src = x.data();
    let dst = out.data_mut();
    let area = (h * w) as i32;
    for nn in 0..n {
        for cc in 0..c {
            let plane = (nn * c + cc) * h * w;
            let sum: i32 = src[plane..plane + h * w].iter().map(|&v| v as i32).sum();
            dst[nn * c + cc] = (sum / area).clamp(-128, 127) as i8;
        }
    }
    out
}

/// Saturating int8 element-wise addition (residual connections).
pub fn add_i8(a: &Tensor<i8>, b: &Tensor<i8>) -> Tensor<i8> {
    assert_eq!(a.shape(), b.shape());
    let mut out = Tensor::zeros(a.shape());
    for (o, (&x, &y)) in out.data_mut().iter_mut().zip(a.data().iter().zip(b.data())) {
        *o = Graph::saturating_add(x, y);
    }
    out
}

/// ReLU.
pub fn relu_i8(x: &Tensor<i8>) -> Tensor<i8> {
    let mut out = Tensor::zeros(x.shape());
    for (o, &v) in out.data_mut().iter_mut().zip(x.data()) {
        *o = v.max(0);
    }
    out
}

/// Dense layer `[M, K] x [N, K]^T → [M, N]` with requantization.
pub fn dense_i8(p: &MatmulParams, x: &Tensor<i8>, w: &Tensor<i8>) -> Tensor<i8> {
    matmul_ref(p, x, w)
}
