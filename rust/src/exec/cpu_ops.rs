//! Native CPU implementations of the operators the paper leaves on the
//! ARM core (§5). The kernels themselves live with the compiler's
//! reference oracles ([`crate::compiler::reference`]) — the operator
//! registry uses one implementation as both the CPU execution path and
//! the accelerator verification oracle; this module re-exports them
//! under their historical `exec` paths.

pub use crate::compiler::reference::{add_i8, dense_i8, global_avg_pool_i8, maxpool_i8, relu_i8};
