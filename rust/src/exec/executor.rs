//! The heterogeneous graph executor: walks a partitioned graph, running
//! VTA nodes through the compiler → runtime → simulator stack and CPU
//! nodes on either native Rust kernels or PJRT executables.
//!
//! The per-node report separates *simulated accelerator time* (cycles ÷
//! clock) from *measured CPU wall time* — the two quantities Fig 16
//! stacks against each other.

use super::cpu_ops;
use super::pjrt::{PjrtCache, PjrtError};
use crate::compiler::{
    self, lower_conv2d, pack_activations, pack_weights, unpack_outputs, CompileError,
};
use crate::graph::{Graph, Op, Placement};
use crate::runtime::VtaRuntime;
use crate::sim::SimStats;
use crate::util::Tensor;
use std::time::{Duration, Instant};
use thiserror::Error;

/// Executor errors.
#[derive(Debug, Error)]
pub enum ExecError {
    #[error("node {0}: {1}")]
    Compile(String, CompileError),
    #[error("node {0}: missing weights")]
    MissingWeights(String),
    #[error("node {node}: pjrt error: {err}")]
    Pjrt { node: String, err: PjrtError },
    #[error("node {0}: op {1} cannot run on the VTA device")]
    NotOffloadable(String, &'static str),
    #[error("plan cache: {0}")]
    PlanCache(CompileError),
}

/// How CPU-resident nodes execute.
pub enum CpuBackend {
    /// Native Rust kernels (always available; used by unit tests and
    /// benches so `cargo test` has no artifact dependency).
    Native,
    /// AOT-compiled XLA executables (the flagship three-layer path).
    /// Falls back to native for ops without a matching artifact.
    Pjrt(PjrtCache),
}

/// Per-node execution record.
#[derive(Clone, Debug)]
pub struct NodeReport {
    pub name: String,
    pub kind: &'static str,
    pub placement: Placement,
    /// CPU wall time (CPU nodes) or host-side orchestration time
    /// (VTA nodes: pack/lower/unpack, excludes simulated time).
    pub wall: Duration,
    /// Simulated accelerator time (VTA nodes).
    pub sim_seconds: f64,
    /// Simulator statistics (VTA nodes).
    pub stats: Option<SimStats>,
    /// Integer ops.
    pub ops: u64,
}

/// Whole-graph execution report.
#[derive(Debug)]
pub struct ExecReport {
    pub nodes: Vec<NodeReport>,
    /// Final output tensor.
    pub output: Tensor<i8>,
}

impl ExecReport {
    /// Total CPU wall time of CPU-resident nodes.
    pub fn cpu_time(&self) -> Duration {
        self.nodes
            .iter()
            .filter(|n| n.placement != Placement::Vta)
            .map(|n| n.wall)
            .sum()
    }

    /// Total simulated VTA time.
    pub fn vta_seconds(&self) -> f64 {
        self.nodes.iter().map(|n| n.sim_seconds).sum()
    }

    /// Merged VTA statistics.
    pub fn vta_stats(&self) -> SimStats {
        let mut s = SimStats::default();
        for n in self.nodes.iter().filter_map(|n| n.stats.as_ref()) {
            s.merge(n);
        }
        s
    }

    /// End-to-end model time: CPU wall + simulated accelerator time
    /// (the hybrid pipeline is synchronous per node, as in the paper's
    /// runtime).
    pub fn total_seconds(&self) -> f64 {
        self.cpu_time().as_secs_f64() + self.vta_seconds()
    }
}

/// Graph executor.
pub struct Executor {
    rt: VtaRuntime,
    cpu: CpuBackend,
}

impl Executor {
    /// Build over a fresh VTA runtime (`dram_size` bytes) and a CPU
    /// backend.
    pub fn new(rt: VtaRuntime, cpu: CpuBackend) -> Self {
        Executor { rt, cpu }
    }

    /// Run the graph on one input. Nodes must already be partitioned.
    ///
    /// Thin wrapper over the staged path: the graph is walked in
    /// topological stages ([`crate::graph::stages`]) — the same order
    /// the pipelined serving engine uses — executing every node
    /// synchronously. This is the *naive serial* baseline the serving
    /// layer's pipelined schedule is measured against.
    pub fn run(&mut self, g: &Graph, input: &Tensor<i8>) -> Result<ExecReport, ExecError> {
        let stages = crate::graph::stages(g);
        self.run_staged(g, input, &stages)
    }

    /// Staged serial execution: stages in order, every node of a stage
    /// in turn, each node fully finished (pack → lower → simulate →
    /// unpack) before the next starts.
    fn run_staged(
        &mut self,
        g: &Graph,
        input: &Tensor<i8>,
        stages: &[Vec<usize>],
    ) -> Result<ExecReport, ExecError> {
        let mut values: Vec<Option<Tensor<i8>>> = vec![None; g.nodes.len()];
        let mut reports: Vec<Option<NodeReport>> = (0..g.nodes.len()).map(|_| None).collect();

        for stage in stages {
            for &id in stage {
                let node = &g.nodes[id];
                let t0 = Instant::now();
                let mut sim_seconds = 0.0;
                let mut stats = None;

                let out = match (&node.op, node.placement) {
                    (Op::Input { .. }, _) => input.clone(),
                    (Op::Conv2d { p }, Placement::Vta) => {
                        let x = values[node.inputs[0]].as_ref().unwrap();
                        let w = g
                            .weights(node.id)
                            .ok_or_else(|| ExecError::MissingWeights(node.name.clone()))?;
                        let cfg = self.rt.ctx.config().clone();
                        let ip = pack_activations(&cfg, x);
                        let wp = pack_weights(&cfg, w);
                        let r = lower_conv2d(&mut self.rt, p, &ip, &wp, 2)
                            .map_err(|e| ExecError::Compile(node.name.clone(), e))?;
                        sim_seconds = r.stats.total_cycles as f64 / cfg.clock_hz;
                        stats = Some(r.stats.clone());
                        unpack_outputs(&cfg, &r.out, x.shape()[0], p.oc, p.out_h(), p.out_w())
                    }
                    (op, Placement::Vta) => {
                        return Err(ExecError::NotOffloadable(node.name.clone(), op.kind()))
                    }
                    (_, _) => exec_cpu_node(&mut self.cpu, g, id, &values)?,
                };

                reports[id] = Some(NodeReport {
                    name: node.name.clone(),
                    kind: node.op.kind(),
                    placement: node.placement,
                    wall: t0.elapsed(),
                    sim_seconds,
                    stats,
                    ops: node.op.ops(&node.shape),
                });
                values[id] = Some(out);
            }
        }

        let out_id = g.output().expect("non-empty graph");
        Ok(ExecReport {
            nodes: reports.into_iter().map(|r| r.expect("stages cover every node")).collect(),
            output: values[out_id].take().unwrap(),
        })
    }
}

/// Execute one CPU-resident node: PJRT artifact when that backend is
/// selected and an artifact exists, native Rust kernels otherwise.
/// Shared by the serial [`Executor`] and the serving engine
/// ([`super::serve::ServingEngine`]).
pub(crate) fn exec_cpu_node(
    cpu: &mut CpuBackend,
    g: &Graph,
    id: usize,
    values: &[Option<Tensor<i8>>],
) -> Result<Tensor<i8>, ExecError> {
    let node = &g.nodes[id];
    let op = &node.op;
    let arg = |i: usize| values[node.inputs[i]].as_ref().unwrap();
    // Try the PJRT artifact first when that backend is selected.
    if let CpuBackend::Pjrt(cache) = cpu {
        if let Some(name) = artifact_name(op, &node.shape) {
            if cache.has(&name) {
                let mut inputs: Vec<&Tensor<i8>> =
                    node.inputs.iter().map(|&i| values[i].as_ref().unwrap()).collect();
                let w_holder;
                if let Some(w) = g.weights(id) {
                    w_holder = w.clone();
                    inputs.push(&w_holder);
                }
                let mut outs = cache
                    .run_i8(&name, &inputs)
                    .map_err(|err| ExecError::Pjrt { node: node.name.clone(), err })?;
                return Ok(outs.remove(0));
            }
        }
    }
    // Native fallback.
    Ok(match op {
        Op::Input { .. } => unreachable!("handled by caller"),
        Op::Conv2d { p } => {
            let w = g
                .weights(id)
                .ok_or_else(|| ExecError::MissingWeights(node.name.clone()))?;
            compiler::reference::conv2d_ref(p, arg(0), w)
        }
        Op::Relu => cpu_ops::relu_i8(arg(0)),
        Op::MaxPool { k, s, pad } => cpu_ops::maxpool_i8(arg(0), *k, *s, *pad),
        Op::GlobalAvgPool => cpu_ops::global_avg_pool_i8(arg(0)),
        Op::Add => cpu_ops::add_i8(arg(0), arg(1)),
        Op::Dense { p } => {
            let w = g
                .weights(id)
                .ok_or_else(|| ExecError::MissingWeights(node.name.clone()))?;
            cpu_ops::dense_i8(p, arg(0), w)
        }
    })
}

/// Artifact naming scheme shared with `python/compile/aot.py`:
/// one executable per (op kind, output shape).
pub fn artifact_name(op: &Op, out_shape: &[usize]) -> Option<String> {
    let shape_tag = |s: &[usize]| s.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x");
    match op {
        Op::Conv2d { p } => Some(format!(
            "conv_{}_{}_{}_{}_{}_{}",
            p.h, p.ic, p.oc, p.k, p.s, p.requant.relu as u8
        )),
        Op::MaxPool { k, s, .. } => Some(format!("maxpool_{}_{}_{}", shape_tag(out_shape), k, s)),
        Op::GlobalAvgPool => Some(format!("gap_{}", shape_tag(out_shape))),
        Op::Add => Some(format!("add_{}", shape_tag(out_shape))),
        Op::Dense { p } => Some(format!("dense_{}_{}_{}", p.m, p.k, p.n)),
        Op::Relu | Op::Input { .. } => None,
    }
}
