//! The heterogeneous graph executor: walks a partitioned graph, running
//! VTA nodes through the compiler → runtime → simulator stack and CPU
//! nodes on either native Rust kernels or PJRT executables.
//!
//! Dispatch is **op-generic**: every node resolves to its registered
//! [`VtaOp`](crate::compiler::VtaOp) implementation
//! ([`crate::compiler::op_impl`]) — the executor never matches on `Op`
//! variants, so newly registered operators run here without touching
//! this file.
//!
//! The per-node report separates *simulated accelerator time* (cycles ÷
//! clock) from *measured CPU wall time* — the two quantities Fig 16
//! stacks against each other.

use super::pjrt::{PjrtCache, PjrtError};
use crate::compiler::op::{execute_compiled, op_impl};
use crate::compiler::CompileError;
use crate::graph::{Graph, Placement};
use crate::runtime::VtaRuntime;
use crate::sim::SimStats;
use crate::util::Tensor;
use std::time::{Duration, Instant};
use thiserror::Error;

/// Executor errors.
#[derive(Debug, Error)]
pub enum ExecError {
    #[error("node {0}: {1}")]
    Compile(String, CompileError),
    #[error("node {0}: missing weights")]
    MissingWeights(String),
    #[error("node {node}: pjrt error: {err}")]
    Pjrt { node: String, err: PjrtError },
    #[error("node {0}: op {1} cannot run on the VTA device")]
    NotOffloadable(String, &'static str),
    #[error("plan cache: {0}")]
    PlanCache(CompileError),
}

/// Lift a compiler-layer error into the executor's error space,
/// attaching the node name (shared with the serving engine).
pub(crate) fn lift_compile_err(name: &str, e: CompileError) -> ExecError {
    match e {
        CompileError::NotOffloadable(kind) => ExecError::NotOffloadable(name.to_string(), kind),
        CompileError::MissingWeights => ExecError::MissingWeights(name.to_string()),
        e => ExecError::Compile(name.to_string(), e),
    }
}

/// How CPU-resident nodes execute.
pub enum CpuBackend {
    /// Native Rust kernels (always available; used by unit tests and
    /// benches so `cargo test` has no artifact dependency).
    Native,
    /// AOT-compiled XLA executables (the flagship three-layer path).
    /// Falls back to native for ops without a matching artifact.
    Pjrt(PjrtCache),
}

/// Per-node execution record.
#[derive(Clone, Debug)]
pub struct NodeReport {
    pub name: String,
    pub kind: &'static str,
    pub placement: Placement,
    /// CPU wall time (CPU nodes) or host-side orchestration time
    /// (VTA nodes: pack/lower/unpack, excludes simulated time).
    pub wall: Duration,
    /// Simulated accelerator time (VTA nodes).
    pub sim_seconds: f64,
    /// Simulator statistics (VTA nodes).
    pub stats: Option<SimStats>,
    /// Integer ops.
    pub ops: u64,
}

/// Whole-graph execution report.
#[derive(Debug)]
pub struct ExecReport {
    pub nodes: Vec<NodeReport>,
    /// Final output tensor.
    pub output: Tensor<i8>,
}

impl ExecReport {
    /// Total CPU wall time of CPU-resident nodes.
    pub fn cpu_time(&self) -> Duration {
        self.nodes
            .iter()
            .filter(|n| n.placement != Placement::Vta)
            .map(|n| n.wall)
            .sum()
    }

    /// Total simulated VTA time.
    pub fn vta_seconds(&self) -> f64 {
        self.nodes.iter().map(|n| n.sim_seconds).sum()
    }

    /// Merged VTA statistics.
    pub fn vta_stats(&self) -> SimStats {
        let mut s = SimStats::default();
        for n in self.nodes.iter().filter_map(|n| n.stats.as_ref()) {
            s.merge(n);
        }
        s
    }

    /// End-to-end model time: CPU wall + simulated accelerator time
    /// (the hybrid pipeline is synchronous per node, as in the paper's
    /// runtime).
    pub fn total_seconds(&self) -> f64 {
        self.cpu_time().as_secs_f64() + self.vta_seconds()
    }
}

/// Graph executor.
pub struct Executor {
    rt: VtaRuntime,
    cpu: CpuBackend,
    virtual_threads: usize,
}

impl Executor {
    /// Build over a fresh VTA runtime (`dram_size` bytes) and a CPU
    /// backend; VTA nodes lower with 2 virtual threads (latency hiding
    /// on — the paper's default, and the default of
    /// `PartitionPolicy::virtual_threads`, whose capability checks
    /// must use the same count).
    pub fn new(rt: VtaRuntime, cpu: CpuBackend) -> Self {
        Executor { rt, cpu, virtual_threads: 2 }
    }

    /// Like [`Self::new`], with an explicit virtual-thread count
    /// ∈ {1, 2}.
    pub fn with_virtual_threads(rt: VtaRuntime, cpu: CpuBackend, virtual_threads: usize) -> Self {
        assert!(
            virtual_threads == 1 || virtual_threads == 2,
            "1 or 2 virtual threads"
        );
        Executor { rt, cpu, virtual_threads }
    }

    /// Run the graph on one input. Nodes must already be partitioned.
    ///
    /// Thin wrapper over the staged path: the graph is walked in
    /// topological stages ([`crate::graph::stages`]) — the same order
    /// the pipelined serving engine uses — executing every node
    /// synchronously. This is the *naive serial* baseline the serving
    /// layer's pipelined schedule is measured against.
    pub fn run(&mut self, g: &Graph, input: &Tensor<i8>) -> Result<ExecReport, ExecError> {
        let stages = crate::graph::stages(g);
        self.run_staged(g, input, &stages)
    }

    /// Staged serial execution: stages in order, every node of a stage
    /// in turn, each node fully finished (pack → compile → simulate →
    /// unpack → free) before the next starts. VTA nodes re-compile on
    /// every inference — the naive baseline the plan cache removes.
    fn run_staged(
        &mut self,
        g: &Graph,
        input: &Tensor<i8>,
        stages: &[Vec<usize>],
    ) -> Result<ExecReport, ExecError> {
        let clock_hz = self.rt.ctx.config().clock_hz;
        let mut values: Vec<Option<Tensor<i8>>> = vec![None; g.nodes.len()];
        let mut reports: Vec<Option<NodeReport>> = (0..g.nodes.len()).map(|_| None).collect();

        for stage in stages {
            for &id in stage {
                let node = &g.nodes[id];
                let entry = op_impl(&node.op);
                let t0 = Instant::now();
                let mut sim_seconds = 0.0;
                let mut stats = None;

                let out = if entry.is_input() {
                    input.clone()
                } else if node.placement == Placement::Vta {
                    let inputs: Vec<&Tensor<i8>> =
                        node.inputs.iter().map(|&i| values[i].as_ref().unwrap()).collect();
                    let compiled = entry
                        .compile(&mut self.rt, g, node, self.virtual_threads, None)
                        .map_err(|e| lift_compile_err(&node.name, e))?;
                    // Release the plan's DRAM residency even when the
                    // run fails: the executor is long-lived and a leak
                    // here would drain the allocator across requests.
                    let result = execute_compiled(entry, &compiled, &mut self.rt, &inputs);
                    compiled
                        .free(&mut self.rt)
                        .map_err(|e| lift_compile_err(&node.name, e))?;
                    let (out, s) = result.map_err(|e| lift_compile_err(&node.name, e))?;
                    sim_seconds = s.total_cycles as f64 / clock_hz;
                    stats = Some(s);
                    out
                } else {
                    exec_cpu_node(&mut self.cpu, g, id, &values)?
                };

                reports[id] = Some(NodeReport {
                    name: node.name.clone(),
                    kind: node.op.kind(),
                    placement: node.placement,
                    wall: t0.elapsed(),
                    sim_seconds,
                    stats,
                    ops: node.op.ops(&node.shape),
                });
                values[id] = Some(out);
            }
        }

        let out_id = g.output().expect("non-empty graph");
        Ok(ExecReport {
            nodes: reports.into_iter().map(|r| r.expect("stages cover every node")).collect(),
            output: values[out_id].take().unwrap(),
        })
    }
}

/// Execute one CPU-resident node: PJRT artifact when that backend is
/// selected and an artifact exists, native reference kernels otherwise
/// — both resolved through the operator registry. Shared by the serial
/// [`Executor`] and the serving engine
/// ([`super::serve::ServingEngine`]).
pub(crate) fn exec_cpu_node(
    cpu: &mut CpuBackend,
    g: &Graph,
    id: usize,
    values: &[Option<Tensor<i8>>],
) -> Result<Tensor<i8>, ExecError> {
    let node = &g.nodes[id];
    let entry = op_impl(&node.op);
    // Try the PJRT artifact first when that backend is selected.
    if let CpuBackend::Pjrt(cache) = cpu {
        if let Some(name) = entry.artifact_name(node) {
            if cache.has(&name) {
                let mut inputs: Vec<&Tensor<i8>> =
                    node.inputs.iter().map(|&i| values[i].as_ref().unwrap()).collect();
                let w_holder;
                if let Some(w) = g.weights(id) {
                    w_holder = w.clone();
                    inputs.push(&w_holder);
                }
                let mut outs = cache
                    .run_i8(&name, &inputs)
                    .map_err(|err| ExecError::Pjrt { node: node.name.clone(), err })?;
                return Ok(outs.remove(0));
            }
        }
    }
    // Native fallback: the operator's reference semantics.
    let inputs: Vec<&Tensor<i8>> =
        node.inputs.iter().map(|&i| values[i].as_ref().unwrap()).collect();
    entry
        .reference(g, node, &inputs)
        .map_err(|e| lift_compile_err(&node.name, e))
}
