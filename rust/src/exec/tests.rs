use super::*;
use crate::arch::VtaConfig;
use crate::compiler::{Conv2dParams, MatmulParams, Requant};
use crate::graph::{fuse, partition, resnet::*, Graph, Op, PartitionPolicy, Placement};
use crate::runtime::VtaRuntime;
use crate::util::{Tensor, XorShiftRng};

fn rand_t(seed: u64, shape: &[usize]) -> Tensor<i8> {
    let mut rng = XorShiftRng::new(seed);
    Tensor::from_vec(shape, rng.vec_i8(shape.iter().product(), -8, 8)).unwrap()
}

#[test]
fn maxpool_semantics() {
    let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1i8, -3, 7, 0]).unwrap();
    let y = maxpool_i8(&x, 2, 2, 0);
    assert_eq!(y.shape(), &[1, 1, 1, 1]);
    assert_eq!(y.data(), &[7]);
    // Padding taps are skipped, not treated as zero: all-negative pools
    // stay negative (zero-padding would yield 0 here).
    let x = Tensor::from_vec(&[1, 1, 2, 2], vec![-5i8, -3, -7, -9]).unwrap();
    let y = maxpool_i8(&x, 3, 2, 1);
    assert_eq!(y.data()[0], -3);
}

#[test]
fn gap_truncating_mean() {
    let x = Tensor::from_vec(&[1, 2, 1, 2], vec![3i8, 4, -3, -4]).unwrap();
    let y = global_avg_pool_i8(&x);
    assert_eq!(y.shape(), &[1, 2]);
    assert_eq!(y.data(), &[3, -3]); // 7/2 = 3, -7/2 = -3 (trunc toward 0)
}

#[test]
fn add_saturates() {
    let a = Tensor::from_vec(&[2], vec![120i8, -120]).unwrap();
    let b = Tensor::from_vec(&[2], vec![60i8, -60]).unwrap();
    assert_eq!(add_i8(&a, &b).data(), &[127, -128]);
}

#[test]
fn relu_zeroes_negatives() {
    let x = Tensor::from_vec(&[3], vec![-1i8, 0, 5]).unwrap();
    assert_eq!(relu_i8(&x).data(), &[0, 0, 5]);
}

/// Tiny hybrid graph: CPU conv (shallow channels) → VTA conv → CPU
/// pooling; the executor must produce exactly the native all-CPU result.
#[test]
fn hybrid_graph_matches_cpu_only() {
    let cfg = VtaConfig::pynq();
    let rq = Requant { shift: 5, relu: true };
    let build = || -> Graph {
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 3, 12, 12] }, &[]).unwrap();
        let p1 = Conv2dParams { h: 12, w: 12, ic: 3, oc: 16, k: 3, s: 1, requant: rq };
        let c1 = g.add("c1", Op::Conv2d { p: p1 }, &[x]).unwrap();
        g.set_weights(c1, rand_t(1, &[16, 3, 3, 3]));
        let p2 = Conv2dParams { h: 12, w: 12, ic: 16, oc: 32, k: 3, s: 2, requant: rq };
        let c2 = g.add("c2", Op::Conv2d { p: p2 }, &[c1]).unwrap();
        g.set_weights(c2, rand_t(2, &[32, 16, 3, 3]));
        let _p = g.add("pool", Op::MaxPool { k: 2, s: 2, pad: 0 }, &[c2]).unwrap();
        g
    };
    let input = rand_t(3, &[1, 3, 12, 12]);

    let mut g_hybrid = build();
    let (vta, _) = partition(&mut g_hybrid, &PartitionPolicy::paper(&cfg));
    assert_eq!(vta, 1); // only c2 offloads (c1 has 3 input channels)

    let mut g_cpu = build();
    partition(&mut g_cpu, &PartitionPolicy::cpu_only());

    let mut ex1 = Executor::new(VtaRuntime::new(&cfg, 32 << 20), CpuBackend::Native);
    let r1 = ex1.run(&g_hybrid, &input).unwrap();
    let mut ex2 = Executor::new(VtaRuntime::new(&cfg, 32 << 20), CpuBackend::Native);
    let r2 = ex2.run(&g_cpu, &input).unwrap();

    assert_eq!(r1.output, r2.output, "hybrid and CPU-only disagree");
    assert!(r1.vta_seconds() > 0.0);
    assert_eq!(r2.vta_seconds(), 0.0);
    assert_eq!(r1.vta_stats().insn_gemm > 0, true);
}

/// Executor rejects offloading ops the device cannot run.
#[test]
fn non_offloadable_op_is_an_error() {
    let cfg = VtaConfig::pynq();
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 4, 4] }, &[]).unwrap();
    let m = g.add("pool", Op::MaxPool { k: 2, s: 2, pad: 0 }, &[x]).unwrap();
    g.nodes[m].placement = Placement::Vta;
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 8 << 20), CpuBackend::Native);
    let err = ex.run(&g, &rand_t(5, &[1, 16, 4, 4])).unwrap_err();
    assert!(matches!(err, ExecError::NotOffloadable(..)));
}

/// Small end-to-end residual block through the full stack.
#[test]
fn residual_block_hybrid() {
    let cfg = VtaConfig::pynq();
    let rq = Requant { shift: 6, relu: false };
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let p = Conv2dParams { h: 8, w: 8, ic: 16, oc: 16, k: 3, s: 1, requant: rq };
    let c1 = g.add("c1", Op::Conv2d { p }, &[x]).unwrap();
    g.set_weights(c1, rand_t(11, &[16, 16, 3, 3]));
    let c2 = g.add("c2", Op::Conv2d { p }, &[c1]).unwrap();
    g.set_weights(c2, rand_t(12, &[16, 16, 3, 3]));
    let add = g.add("add", Op::Add, &[c2, x]).unwrap();
    let _r = g.add("relu", Op::Relu, &[add]).unwrap();

    let run = |g: &Graph, input: &Tensor<i8>| {
        let mut ex = Executor::new(VtaRuntime::new(&cfg, 16 << 20), CpuBackend::Native);
        ex.run(g, input).unwrap().output
    };
    let input = rand_t(13, &[1, 16, 8, 8]);

    let mut g1 = g;
    partition(&mut g1, &PartitionPolicy::paper(&cfg));
    let hybrid = run(&g1, &input);
    partition(&mut g1, &PartitionPolicy::cpu_only());
    let cpu = run(&g1, &input);
    assert_eq!(hybrid, cpu);
}

/// Regression for the `offload_dense` partition-policy bug: a Dense
/// node placed on the VTA used to fail at execution with
/// `NotOffloadable`; through the operator registry it now lowers onto
/// the GEMM intrinsic and runs end-to-end.
#[test]
fn dense_offload_executes_end_to_end() {
    let cfg = VtaConfig::pynq();
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 64] }, &[]).unwrap();
    let p = MatmulParams { m: 1, k: 64, n: 32, requant: Requant { shift: 4, relu: false } };
    let d = g.add("fc", Op::Dense { p }, &[x]).unwrap();
    g.set_weights(d, rand_t(21, &[32, 64]));
    let input = rand_t(22, &[1, 64]);

    let mut policy = PartitionPolicy::paper(&cfg);
    policy.offload_dense = true;
    let (vta, _) = partition(&mut g, &policy);
    assert_eq!(vta, 1, "dense must offload under offload_dense");

    let mut ex = Executor::new(VtaRuntime::new(&cfg, 16 << 20), CpuBackend::Native);
    let hybrid = ex.run(&g, &input).unwrap();
    assert!(hybrid.vta_seconds() > 0.0, "the dense node must have run on the VTA");

    partition(&mut g, &PartitionPolicy::cpu_only());
    let mut ex2 = Executor::new(VtaRuntime::new(&cfg, 16 << 20), CpuBackend::Native);
    let cpu = ex2.run(&g, &input).unwrap();
    assert_eq!(hybrid.output, cpu.output, "VTA dense diverged from the CPU reference");
}

/// The acceptance scenario of the operator-registry redesign: a
/// ResNet-style graph with conv, dense, AND ALU-class elementwise ops
/// (residual add + standalone relu) all offloaded runs through
/// `Executor::run` and matches the CPU-only reference bit-exactly.
#[test]
fn mixed_offload_graph_matches_cpu_only() {
    let cfg = VtaConfig::pynq();
    let rq = Requant { shift: 6, relu: false };
    let build = || -> Graph {
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
        let p = Conv2dParams { h: 8, w: 8, ic: 16, oc: 16, k: 3, s: 1, requant: rq };
        let c1 = g.add("c1", Op::Conv2d { p }, &[x]).unwrap();
        g.set_weights(c1, rand_t(31, &[16, 16, 3, 3]));
        let c2 = g.add("c2", Op::Conv2d { p }, &[c1]).unwrap();
        g.set_weights(c2, rand_t(32, &[16, 16, 3, 3]));
        let add = g.add("add", Op::Add, &[c2, x]).unwrap();
        let r = g.add("relu", Op::Relu, &[add]).unwrap();
        let gap = g.add("gap", Op::GlobalAvgPool, &[r]).unwrap();
        let fcp = MatmulParams { m: 1, k: 16, n: 10, requant: Requant { shift: 2, relu: false } };
        let fc = g.add("fc", Op::Dense { p: fcp }, &[gap]).unwrap();
        g.set_weights(fc, rand_t(33, &[10, 16]));
        g
    };
    let input = rand_t(34, &[1, 16, 8, 8]);

    let mut g_all = build();
    let (vta, cpu) = partition(&mut g_all, &PartitionPolicy::offload_all(&cfg));
    assert_eq!(vta, 5, "conv x2 + add + relu + dense offload");
    assert_eq!(cpu, 2, "input + gap stay on the CPU");

    let mut g_cpu = build();
    partition(&mut g_cpu, &PartitionPolicy::cpu_only());

    let mut ex1 = Executor::new(VtaRuntime::new(&cfg, 32 << 20), CpuBackend::Native);
    let r1 = ex1.run(&g_all, &input).unwrap();
    let mut ex2 = Executor::new(VtaRuntime::new(&cfg, 32 << 20), CpuBackend::Native);
    let r2 = ex2.run(&g_cpu, &input).unwrap();
    assert_eq!(r1.output, r2.output, "mixed offload and CPU-only disagree");

    // The ALU nodes really ran on the device: their reports carry
    // simulator statistics with ALU micro-ops.
    let alu_stats: u64 = r1
        .nodes
        .iter()
        .filter(|n| n.kind == "add" || n.kind == "relu")
        .filter_map(|n| n.stats.as_ref())
        .map(|s| s.alu_uops)
        .sum();
    assert!(alu_stats > 0, "add/relu must execute ALU micro-ops on the VTA");
}

/// The partition pass consults the registry's cost model: a floor
/// above a node's integer-op count keeps it on the CPU even when the
/// policy would otherwise offload it.
#[test]
fn partition_cost_floor_keeps_small_nodes_on_cpu() {
    let cfg = VtaConfig::pynq();
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let p = Conv2dParams {
        h: 8,
        w: 8,
        ic: 16,
        oc: 16,
        k: 3,
        s: 1,
        requant: Requant { shift: 6, relu: false },
    };
    let c = g.add("c", Op::Conv2d { p }, &[x]).unwrap();
    g.set_weights(c, rand_t(41, &[16, 16, 3, 3]));

    let mut policy = PartitionPolicy::paper(&cfg);
    let (vta, _) = partition(&mut g, &policy);
    assert_eq!(vta, 1);
    policy.min_offload_ops = p.ops() + 1;
    let (vta, _) = partition(&mut g, &policy);
    assert_eq!(vta, 0, "cost floor must keep the conv on the CPU");
}

/// ResNet-18 smoke: partitioned execution agrees with CPU-only on a
/// small crop... the full 224x224 is exercised by the e2e example and
/// bench; here a reduced-depth check keeps test time sane: run just
/// the graph build + a few nodes by truncating to the first residual
/// stage would complicate the builder, so instead assert the report
/// structure on the full model with a single run (native CPU).
#[test]
#[ignore = "slow: full ResNet-18 on the simulator; run explicitly or via the e2e bench"]
fn resnet18_hybrid_full() {
    let cfg = VtaConfig::pynq();
    let (mut g, _) = fuse(resnet18(1, 42).unwrap());
    partition(&mut g, &PartitionPolicy::paper(&cfg));
    let input = synth_input(7, 1, 3, 224, 224);
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 256 << 20), CpuBackend::Native);
    let r = ex.run(&g, &input).unwrap();
    assert_eq!(r.output.shape(), &[1, 1000]);
    assert!(r.vta_seconds() > 0.0);
}
