use super::*;
use crate::arch::VtaConfig;
use crate::compiler::{Conv2dParams, MatmulParams, Requant};
use crate::graph::{fuse, partition, resnet::*, Graph, Op, PartitionPolicy, Placement};
use crate::runtime::VtaRuntime;
use crate::util::{Tensor, XorShiftRng};

fn rand_t(seed: u64, shape: &[usize]) -> Tensor<i8> {
    let mut rng = XorShiftRng::new(seed);
    Tensor::from_vec(shape, rng.vec_i8(shape.iter().product(), -8, 8)).unwrap()
}

#[test]
fn maxpool_semantics() {
    let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1i8, -3, 7, 0]).unwrap();
    let y = maxpool_i8(&x, 2, 2, 0);
    assert_eq!(y.shape(), &[1, 1, 1, 1]);
    assert_eq!(y.data(), &[7]);
    // Padding taps are skipped, not treated as zero: all-negative pools
    // stay negative (zero-padding would yield 0 here).
    let x = Tensor::from_vec(&[1, 1, 2, 2], vec![-5i8, -3, -7, -9]).unwrap();
    let y = maxpool_i8(&x, 3, 2, 1);
    assert_eq!(y.data()[0], -3);
}

#[test]
fn gap_truncating_mean() {
    let x = Tensor::from_vec(&[1, 2, 1, 2], vec![3i8, 4, -3, -4]).unwrap();
    let y = global_avg_pool_i8(&x);
    assert_eq!(y.shape(), &[1, 2]);
    assert_eq!(y.data(), &[3, -3]); // 7/2 = 3, -7/2 = -3 (trunc toward 0)
}

#[test]
fn add_saturates() {
    let a = Tensor::from_vec(&[2], vec![120i8, -120]).unwrap();
    let b = Tensor::from_vec(&[2], vec![60i8, -60]).unwrap();
    assert_eq!(add_i8(&a, &b).data(), &[127, -128]);
}

#[test]
fn relu_zeroes_negatives() {
    let x = Tensor::from_vec(&[3], vec![-1i8, 0, 5]).unwrap();
    assert_eq!(relu_i8(&x).data(), &[0, 0, 5]);
}

/// Tiny hybrid graph: CPU conv (shallow channels) → VTA conv → CPU
/// pooling; the executor must produce exactly the native all-CPU result.
#[test]
fn hybrid_graph_matches_cpu_only() {
    let cfg = VtaConfig::pynq();
    let rq = Requant { shift: 5, relu: true };
    let build = || -> Graph {
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 3, 12, 12] }, &[]).unwrap();
        let p1 = Conv2dParams { h: 12, w: 12, ic: 3, oc: 16, k: 3, s: 1, requant: rq };
        let c1 = g.add("c1", Op::Conv2d { p: p1 }, &[x]).unwrap();
        g.set_weights(c1, rand_t(1, &[16, 3, 3, 3]));
        let p2 = Conv2dParams { h: 12, w: 12, ic: 16, oc: 32, k: 3, s: 2, requant: rq };
        let c2 = g.add("c2", Op::Conv2d { p: p2 }, &[c1]).unwrap();
        g.set_weights(c2, rand_t(2, &[32, 16, 3, 3]));
        let _p = g.add("pool", Op::MaxPool { k: 2, s: 2, pad: 0 }, &[c2]).unwrap();
        g
    };
    let input = rand_t(3, &[1, 3, 12, 12]);

    let mut g_hybrid = build();
    let (vta, _) = partition(&mut g_hybrid, &PartitionPolicy::paper(&cfg));
    assert_eq!(vta, 1); // only c2 offloads (c1 has 3 input channels)

    let mut g_cpu = build();
    partition(&mut g_cpu, &PartitionPolicy::cpu_only());

    let mut ex1 = Executor::new(VtaRuntime::new(&cfg, 32 << 20), CpuBackend::Native);
    let r1 = ex1.run(&g_hybrid, &input).unwrap();
    let mut ex2 = Executor::new(VtaRuntime::new(&cfg, 32 << 20), CpuBackend::Native);
    let r2 = ex2.run(&g_cpu, &input).unwrap();

    assert_eq!(r1.output, r2.output, "hybrid and CPU-only disagree");
    assert!(r1.vta_seconds() > 0.0);
    assert_eq!(r2.vta_seconds(), 0.0);
    assert_eq!(r1.vta_stats().insn_gemm > 0, true);
}

/// Executor rejects offloading ops the device cannot run.
#[test]
fn non_offloadable_op_is_an_error() {
    let cfg = VtaConfig::pynq();
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 4, 4] }, &[]).unwrap();
    let m = g.add("pool", Op::MaxPool { k: 2, s: 2, pad: 0 }, &[x]).unwrap();
    g.nodes[m].placement = Placement::Vta;
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 8 << 20), CpuBackend::Native);
    let err = ex.run(&g, &rand_t(5, &[1, 16, 4, 4])).unwrap_err();
    assert!(matches!(err, ExecError::NotOffloadable(..)));
}

/// Small end-to-end residual block through the full stack.
#[test]
fn residual_block_hybrid() {
    let cfg = VtaConfig::pynq();
    let rq = Requant { shift: 6, relu: false };
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let p = Conv2dParams { h: 8, w: 8, ic: 16, oc: 16, k: 3, s: 1, requant: rq };
    let c1 = g.add("c1", Op::Conv2d { p }, &[x]).unwrap();
    g.set_weights(c1, rand_t(11, &[16, 16, 3, 3]));
    let c2 = g.add("c2", Op::Conv2d { p }, &[c1]).unwrap();
    g.set_weights(c2, rand_t(12, &[16, 16, 3, 3]));
    let add = g.add("add", Op::Add, &[c2, x]).unwrap();
    let _r = g.add("relu", Op::Relu, &[add]).unwrap();

    let run = |g: &Graph, input: &Tensor<i8>| {
        let mut ex = Executor::new(VtaRuntime::new(&cfg, 16 << 20), CpuBackend::Native);
        ex.run(g, input).unwrap().output
    };
    let input = rand_t(13, &[1, 16, 8, 8]);

    let mut g1 = g;
    partition(&mut g1, &PartitionPolicy::paper(&cfg));
    let hybrid = run(&g1, &input);
    partition(&mut g1, &PartitionPolicy::cpu_only());
    let cpu = run(&g1, &input);
    assert_eq!(hybrid, cpu);
}

/// Regression for the `offload_dense` partition-policy bug: a Dense
/// node placed on the VTA used to fail at execution with
/// `NotOffloadable`; through the operator registry it now lowers onto
/// the GEMM intrinsic and runs end-to-end.
#[test]
fn dense_offload_executes_end_to_end() {
    let cfg = VtaConfig::pynq();
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 64] }, &[]).unwrap();
    let p = MatmulParams { m: 1, k: 64, n: 32, requant: Requant { shift: 4, relu: false } };
    let d = g.add("fc", Op::Dense { p }, &[x]).unwrap();
    g.set_weights(d, rand_t(21, &[32, 64]));
    let input = rand_t(22, &[1, 64]);

    let mut policy = PartitionPolicy::paper(&cfg);
    policy.offload_dense = true;
    let (vta, _) = partition(&mut g, &policy);
    assert_eq!(vta, 1, "dense must offload under offload_dense");

    let mut ex = Executor::new(VtaRuntime::new(&cfg, 16 << 20), CpuBackend::Native);
    let hybrid = ex.run(&g, &input).unwrap();
    assert!(hybrid.vta_seconds() > 0.0, "the dense node must have run on the VTA");

    partition(&mut g, &PartitionPolicy::cpu_only());
    let mut ex2 = Executor::new(VtaRuntime::new(&cfg, 16 << 20), CpuBackend::Native);
    let cpu = ex2.run(&g, &input).unwrap();
    assert_eq!(hybrid.output, cpu.output, "VTA dense diverged from the CPU reference");
}

/// The acceptance scenario of the operator-registry redesign: a
/// ResNet-style graph with conv, dense, AND ALU-class elementwise ops
/// (residual add + standalone relu) all offloaded runs through
/// `Executor::run` and matches the CPU-only reference bit-exactly.
#[test]
fn mixed_offload_graph_matches_cpu_only() {
    let cfg = VtaConfig::pynq();
    let rq = Requant { shift: 6, relu: false };
    let build = || -> Graph {
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
        let p = Conv2dParams { h: 8, w: 8, ic: 16, oc: 16, k: 3, s: 1, requant: rq };
        let c1 = g.add("c1", Op::Conv2d { p }, &[x]).unwrap();
        g.set_weights(c1, rand_t(31, &[16, 16, 3, 3]));
        let c2 = g.add("c2", Op::Conv2d { p }, &[c1]).unwrap();
        g.set_weights(c2, rand_t(32, &[16, 16, 3, 3]));
        let add = g.add("add", Op::Add, &[c2, x]).unwrap();
        let r = g.add("relu", Op::Relu, &[add]).unwrap();
        let gap = g.add("gap", Op::GlobalAvgPool, &[r]).unwrap();
        let fcp = MatmulParams { m: 1, k: 16, n: 10, requant: Requant { shift: 2, relu: false } };
        let fc = g.add("fc", Op::Dense { p: fcp }, &[gap]).unwrap();
        g.set_weights(fc, rand_t(33, &[10, 16]));
        g
    };
    let input = rand_t(34, &[1, 16, 8, 8]);

    let mut g_all = build();
    let (vta, cpu) = partition(&mut g_all, &PartitionPolicy::offload_all(&cfg));
    assert_eq!(vta, 5, "conv x2 + add + relu + dense offload");
    assert_eq!(cpu, 2, "input + gap stay on the CPU");

    let mut g_cpu = build();
    partition(&mut g_cpu, &PartitionPolicy::cpu_only());

    let mut ex1 = Executor::new(VtaRuntime::new(&cfg, 32 << 20), CpuBackend::Native);
    let r1 = ex1.run(&g_all, &input).unwrap();
    let mut ex2 = Executor::new(VtaRuntime::new(&cfg, 32 << 20), CpuBackend::Native);
    let r2 = ex2.run(&g_cpu, &input).unwrap();
    assert_eq!(r1.output, r2.output, "mixed offload and CPU-only disagree");

    // The ALU nodes really ran on the device: their reports carry
    // simulator statistics with ALU micro-ops.
    let alu_stats: u64 = r1
        .nodes
        .iter()
        .filter(|n| n.kind == "add" || n.kind == "relu")
        .filter_map(|n| n.stats.as_ref())
        .map(|s| s.alu_uops)
        .sum();
    assert!(alu_stats > 0, "add/relu must execute ALU micro-ops on the VTA");
}

/// The partition pass consults the registry's cost model: a floor
/// above a node's integer-op count keeps it on the CPU even when the
/// policy would otherwise offload it.
#[test]
fn partition_cost_floor_keeps_small_nodes_on_cpu() {
    let cfg = VtaConfig::pynq();
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let p = Conv2dParams {
        h: 8,
        w: 8,
        ic: 16,
        oc: 16,
        k: 3,
        s: 1,
        requant: Requant { shift: 6, relu: false },
    };
    let c = g.add("c", Op::Conv2d { p }, &[x]).unwrap();
    g.set_weights(c, rand_t(41, &[16, 16, 3, 3]));

    let mut policy = PartitionPolicy::paper(&cfg);
    let (vta, _) = partition(&mut g, &policy);
    assert_eq!(vta, 1);
    policy.min_offload_ops = p.ops() + 1;
    let (vta, _) = partition(&mut g, &policy);
    assert_eq!(vta, 0, "cost floor must keep the conv on the CPU");
}

/// ResNet-18 smoke: partitioned execution agrees with CPU-only on a
/// small crop... the full 224x224 is exercised by the e2e example and
/// bench; here a reduced-depth check keeps test time sane: run just
/// the graph build + a few nodes by truncating to the first residual
/// stage would complicate the builder, so instead assert the report
/// structure on the full model with a single run (native CPU).
#[test]
#[ignore = "slow: full ResNet-18 on the simulator; run explicitly or via the e2e bench"]
fn resnet18_hybrid_full() {
    let cfg = VtaConfig::pynq();
    let (mut g, _) = fuse(resnet18(1, 42).unwrap()).unwrap();
    partition(&mut g, &PartitionPolicy::paper(&cfg));
    let input = synth_input(7, 1, 3, 224, 224);
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 256 << 20), CpuBackend::Native);
    let r = ex.run(&g, &input).unwrap();
    assert_eq!(r.output.shape(), &[1, 1000]);
    assert!(r.vta_seconds() > 0.0);
}

/// Golden must-not-fold case: a ReLU whose conv producer **also**
/// feeds a residual add must keep the pre-activation value alive, so
/// fusion must leave both nodes untouched — and the guard is
/// load-bearing: manually folding the ReLU into the conv's requant
/// epilogue changes the numerics on this input.
#[test]
fn multi_consumer_relu_must_not_fold() {
    let cfg = VtaConfig::pynq();
    let p = Conv2dParams {
        h: 8,
        w: 8,
        ic: 16,
        oc: 16,
        k: 3,
        s: 1,
        requant: Requant { shift: 6, relu: false },
    };
    // `c` feeds the ReLU *and* the add: `out = relu(c) + c`.
    let build = || -> Graph {
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
        let c = g.add("c", Op::Conv2d { p }, &[x]).unwrap();
        g.set_weights(c, rand_t(51, &[16, 16, 3, 3]));
        let r = g.add("relu", Op::Relu, &[c]).unwrap();
        let _sum = g.add("sum", Op::Add, &[r, c]).unwrap();
        g
    };
    let input = rand_t(52, &[1, 16, 8, 8]);

    // Fusion refuses: no chain (the conv's value escapes), no fold.
    let (g, n) = fuse(build()).unwrap();
    assert_eq!(n, 0, "multi-consumer conv must not fuse or fold");
    assert_eq!(g.nodes.len(), 4, "no node may disappear");
    let c_node = g.nodes.iter().find(|nd| nd.name == "c").unwrap();
    let Op::Conv2d { p: pc } = &c_node.op else { panic!("conv rewritten") };
    assert!(!pc.requant.relu, "relu flag must stay clear on a shared conv");
    assert!(g.nodes.iter().any(|nd| matches!(nd.op, Op::Relu)), "standalone relu survives");

    // CPU-only golden vs the hybrid run of the (un)fused graph.
    let mut g_cpu = build();
    partition(&mut g_cpu, &PartitionPolicy::cpu_only());
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 32 << 20), CpuBackend::Native);
    let expect = ex.run(&g_cpu, &input).unwrap().output;

    let mut g_hyb = g;
    partition(&mut g_hyb, &PartitionPolicy::offload_all(&cfg));
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 32 << 20), CpuBackend::Native);
    let got = ex.run(&g_hyb, &input).unwrap().output;
    assert_eq!(got, expect, "fused graph hybrid run diverged from reference");

    // Counterfactual: fold the ReLU anyway (what a guard-less pass
    // would emit) — `relu(c) + relu(c)` — and verify it really does
    // change the numerics on this input, so the test can't pass
    // vacuously.
    let mut g_bad = Graph::new();
    let x = g_bad.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let mut p_bad = p;
    p_bad.requant.relu = true;
    let cb = g_bad.add("c", Op::Conv2d { p: p_bad }, &[x]).unwrap();
    g_bad.set_weights(cb, rand_t(51, &[16, 16, 3, 3]));
    let _sum = g_bad.add("sum", Op::Add, &[cb, cb]).unwrap();
    partition(&mut g_bad, &PartitionPolicy::cpu_only());
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 32 << 20), CpuBackend::Native);
    let bad = ex.run(&g_bad, &input).unwrap().output;
    assert_ne!(bad, expect, "premise: folding the shared relu must change results");
}

/// A fused `conv+add+relu` chain executes as ONE VTA node: a single
/// report entry carrying both GEMM and ALU micro-ops (the epilogue
/// runs in the conv's ACC residency), bit-exact against CPU-only.
#[test]
fn fused_chain_executes_as_one_vta_node() {
    let cfg = VtaConfig::pynq();
    let p = Conv2dParams {
        h: 8,
        w: 8,
        ic: 16,
        oc: 16,
        k: 3,
        s: 1,
        requant: Requant { shift: 6, relu: false },
    };
    let build = || -> Graph {
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
        let c = g.add("c", Op::Conv2d { p }, &[x]).unwrap();
        g.set_weights(c, rand_t(61, &[16, 16, 3, 3]));
        let a = g.add("add", Op::Add, &[c, x]).unwrap();
        let _r = g.add("relu", Op::Relu, &[a]).unwrap();
        g
    };
    let input = rand_t(62, &[1, 16, 8, 8]);

    let mut g_cpu = build();
    partition(&mut g_cpu, &PartitionPolicy::cpu_only());
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 32 << 20), CpuBackend::Native);
    let expect = ex.run(&g_cpu, &input).unwrap().output;

    let (mut g, n) = fuse(build()).unwrap();
    assert_eq!(n, 2, "add and relu fold into the conv chain");
    partition(&mut g, &PartitionPolicy::offload_all(&cfg));
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 32 << 20), CpuBackend::Native);
    let r = ex.run(&g, &input).unwrap();
    assert_eq!(r.output, expect, "fused chain diverged from CPU reference");

    let fused: Vec<_> = r.nodes.iter().filter(|nd| nd.kind == "fused_conv2d").collect();
    assert_eq!(fused.len(), 1, "exactly one fused node in the report");
    let stats = fused[0].stats.as_ref().expect("fused node ran on the simulator");
    assert!(stats.gemm_uops > 0, "the conv's GEMM work is inside the fused node");
    assert!(stats.alu_uops > 0, "the epilogue's ALU work is inside the fused node");
    // The residual really rode along in the ACC: the fused node loads
    // accumulator-format bytes beyond input + weight + uop traffic.
    assert!(stats.bytes_loaded > 0);
}
