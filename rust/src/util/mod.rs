//! Small shared utilities: deterministic PRNG, dense tensors, and a
//! micro statistics helper for the bench harness.

mod rng;
mod stats;
mod tensor;

pub use rng::XorShiftRng;
pub use stats::{percentile_rank, percentile_sorted, BenchStats};
pub use tensor::{Tensor, TensorError};

#[cfg(test)]
mod tests;
