//! A minimal dense row-major tensor used across the stack for host-side
//! data (DRAM images, reference computations, layout packing).

use thiserror::Error;

/// Errors from tensor construction / reshaping.
#[derive(Debug, Error, PartialEq, Eq)]
pub enum TensorError {
    #[error("shape {shape:?} implies {expected} elements, data has {actual}")]
    ShapeMismatch { shape: Vec<usize>, expected: usize, actual: usize },
    #[error("index {index:?} out of bounds for shape {shape:?}")]
    OutOfBounds { index: Vec<usize>, shape: Vec<usize> },
}

/// Dense row-major tensor over `T`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

impl<T: Copy + Default> Tensor<T> {
    /// Zero-filled (default-filled) tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![T::default(); n] }
    }

    /// Construct from existing data; checks the element count.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Result<Self, TensorError> {
        let expected: usize = shape.iter().product();
        if data.len() != expected {
            return Err(TensorError::ShapeMismatch {
                shape: shape.to_vec(),
                expected,
                actual: data.len(),
            });
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    /// Tensor shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Flat immutable view.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    /// Flat mutable view.
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume into the flat buffer.
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Row-major linear offset of a multi-index.
    pub fn offset(&self, index: &[usize]) -> Result<usize, TensorError> {
        if index.len() != self.shape.len()
            || index.iter().zip(&self.shape).any(|(i, s)| i >= s)
        {
            return Err(TensorError::OutOfBounds {
                index: index.to_vec(),
                shape: self.shape.clone(),
            });
        }
        let mut off = 0;
        for (i, s) in index.iter().zip(&self.shape) {
            off = off * s + i;
        }
        Ok(off)
    }

    /// Element read.
    pub fn at(&self, index: &[usize]) -> Result<T, TensorError> {
        Ok(self.data[self.offset(index)?])
    }

    /// Element write.
    pub fn set(&mut self, index: &[usize], v: T) -> Result<(), TensorError> {
        let off = self.offset(index)?;
        self.data[off] = v;
        Ok(())
    }

    /// Reshape without moving data (element count must match).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self, TensorError> {
        let expected: usize = shape.iter().product();
        if expected != self.data.len() {
            return Err(TensorError::ShapeMismatch {
                shape: shape.to_vec(),
                expected,
                actual: self.data.len(),
            });
        }
        self.shape = shape.to_vec();
        Ok(self)
    }
}
