use super::*;

#[test]
fn rng_is_deterministic() {
    let mut a = XorShiftRng::new(42);
    let mut b = XorShiftRng::new(42);
    for _ in 0..100 {
        assert_eq!(a.next_u64(), b.next_u64());
    }
}

#[test]
fn rng_zero_seed_is_remapped() {
    let mut r = XorShiftRng::new(0);
    assert_ne!(r.next_u64(), 0);
}

#[test]
fn rng_i8_range_is_respected() {
    let mut r = XorShiftRng::new(7);
    for _ in 0..10_000 {
        let v = r.next_i8_in(-3, 5);
        assert!((-3..=5).contains(&v), "out of range: {v}");
    }
    // full-range must not overflow
    for _ in 0..1000 {
        let _ = r.next_i8_in(i8::MIN, i8::MAX);
    }
}

#[test]
fn rng_f64_in_unit_interval() {
    let mut r = XorShiftRng::new(3);
    for _ in 0..1000 {
        let v = r.next_f64();
        assert!((0.0..1.0).contains(&v));
    }
}

#[test]
fn tensor_offsets_are_row_major() {
    let t: Tensor<i32> = Tensor::zeros(&[2, 3, 4]);
    assert_eq!(t.offset(&[0, 0, 0]).unwrap(), 0);
    assert_eq!(t.offset(&[0, 0, 3]).unwrap(), 3);
    assert_eq!(t.offset(&[0, 1, 0]).unwrap(), 4);
    assert_eq!(t.offset(&[1, 2, 3]).unwrap(), 23);
}

#[test]
fn tensor_bounds_checked() {
    let t: Tensor<i32> = Tensor::zeros(&[2, 3]);
    assert!(t.offset(&[2, 0]).is_err());
    assert!(t.offset(&[0, 3]).is_err());
    assert!(t.offset(&[0]).is_err());
}

#[test]
fn tensor_from_vec_checks_count() {
    assert!(Tensor::from_vec(&[2, 2], vec![1i8, 2, 3]).is_err());
    let t = Tensor::from_vec(&[2, 2], vec![1i8, 2, 3, 4]).unwrap();
    assert_eq!(t.at(&[1, 0]).unwrap(), 3);
}

#[test]
fn tensor_reshape() {
    let t = Tensor::from_vec(&[2, 6], (0..12i32).collect()).unwrap();
    let r = t.reshape(&[3, 4]).unwrap();
    assert_eq!(r.at(&[2, 3]).unwrap(), 11);
    assert!(r.reshape(&[5, 5]).is_err());
}

#[test]
fn percentile_sorted_handles_empty_and_single_sample() {
    // Empty: every percentile is zero (and `percentile_rank` reports
    // the degenerate case explicitly).
    assert_eq!(percentile_rank(0, 0.5), None);
    assert_eq!(percentile_sorted(&[], 0.0), 0.0);
    assert_eq!(percentile_sorted(&[], 0.5), 0.0);
    assert_eq!(percentile_sorted(&[], 1.0), 0.0);
    // Single sample: every percentile is that sample.
    assert_eq!(percentile_rank(1, 0.99), Some((0, 0, 0.0)));
    for p in [0.0, 0.25, 0.5, 0.99, 1.0] {
        assert_eq!(percentile_sorted(&[7.5], p), 7.5, "p={p}");
    }
}

#[test]
fn percentile_sorted_interpolates_and_clamps() {
    let s = [0.0, 100.0, 200.0, 300.0];
    // n=4: rank = p * 3. p=0.5 → rank 1.5 → midpoint of 100 and 200.
    assert_eq!(percentile_sorted(&s, 0.5), 150.0);
    // Exact rank hits the sample.
    assert_eq!(percentile_sorted(&s, 1.0 / 3.0), 100.0);
    // Endpoints exact; out-of-range p clamps.
    assert_eq!(percentile_sorted(&s, 0.0), 0.0);
    assert_eq!(percentile_sorted(&s, 1.0), 300.0);
    assert_eq!(percentile_sorted(&s, -1.0), 0.0);
    assert_eq!(percentile_sorted(&s, 2.0), 300.0);
}

#[test]
fn bench_stats_and_percentile_sorted_agree() {
    // One interpolating implementation: the Duration-typed BenchStats
    // view and the f64 view must report identical percentiles.
    let ns: Vec<u128> = vec![10_000, 20_000, 30_000, 40_000, 70_000];
    let mut s = BenchStats::default();
    for &v in &ns {
        s.push_ns(v);
    }
    let f: Vec<f64> = ns.iter().map(|&v| v as f64).collect();
    for p in [0.0, 0.1, 0.5, 0.9, 0.95, 0.99, 1.0] {
        assert_eq!(
            s.percentile(p).as_nanos() as f64,
            percentile_sorted(&f, p).round(),
            "p={p}"
        );
    }
}

#[test]
fn bench_stats_basic() {
    let mut s = BenchStats::default();
    for ns in [10u128, 20, 30, 40, 50] {
        s.push_ns(ns);
    }
    assert_eq!(s.count(), 5);
    assert_eq!(s.median().as_nanos(), 30);
    assert_eq!(s.min().as_nanos(), 10);
    assert_eq!(s.mean().as_nanos(), 30);
}

#[test]
fn bench_stats_empty_is_all_zero() {
    let s = BenchStats::default();
    assert_eq!(s.count(), 0);
    assert_eq!(s.percentile(0.0), std::time::Duration::ZERO);
    assert_eq!(s.percentile(0.5), std::time::Duration::ZERO);
    assert_eq!(s.percentile(1.0), std::time::Duration::ZERO);
    assert_eq!(s.median(), std::time::Duration::ZERO);
    assert_eq!(s.mean(), std::time::Duration::ZERO);
    assert_eq!(s.min(), std::time::Duration::ZERO);
    assert_eq!(s.p99(), std::time::Duration::ZERO);
}

#[test]
fn bench_stats_single_sample_is_every_percentile() {
    let mut s = BenchStats::default();
    s.push_ns(42);
    for p in [0.0, 0.25, 0.5, 0.95, 0.99, 1.0] {
        assert_eq!(s.percentile(p).as_nanos(), 42, "p={p}");
    }
}

#[test]
fn bench_stats_percentile_interpolates_between_ranks() {
    let mut s = BenchStats::default();
    // Out-of-order pushes must still land sorted.
    for ns in [100u128, 0, 300, 200] {
        s.push_ns(ns);
    }
    // n=4: rank = p * 3. p=0.5 → rank 1.5 → midpoint of 100 and 200.
    assert_eq!(s.percentile(0.5).as_nanos(), 150);
    // p=1/3 → rank 1.0 → exactly the second sample.
    assert_eq!(s.percentile(1.0 / 3.0).as_nanos(), 100);
    // Endpoints are exact; out-of-range p clamps.
    assert_eq!(s.percentile(0.0).as_nanos(), 0);
    assert_eq!(s.percentile(1.0).as_nanos(), 300);
    assert_eq!(s.percentile(-1.0).as_nanos(), 0);
    assert_eq!(s.percentile(2.0).as_nanos(), 300);
    // p95 on n=4: rank 2.85 → 200 + 0.85 * 100 = 285.
    assert_eq!(s.p95().as_nanos(), 285);
}

#[test]
fn bench_stats_summary_reports_p50_and_p99() {
    let mut s = BenchStats::default();
    for ns in 1..=100u128 {
        s.push_ns(ns * 1000);
    }
    let text = s.summary();
    assert!(text.contains("p50"), "{text}");
    assert!(text.contains("p99"), "{text}");
    assert!(text.contains("n=100"), "{text}");
    // p99 over 1..=100 µs: rank 98.01 → ~99.01 µs.
    let p99 = s.p99().as_nanos();
    assert!((99_000..=99_020).contains(&p99), "p99 = {p99}");
}
