//! Deterministic xorshift* PRNG (no external `rand` dependency; the
//! whole stack must be reproducible bit-for-bit across runs so that the
//! VTA-simulator outputs can be compared against the AOT-compiled JAX
//! artifacts, which are generated from the same sequences in
//! `python/compile/synth.py`).

/// xorshift64* generator. The exact same algorithm is implemented on the
/// Python side so both halves of the stack synthesize identical tensors.
#[derive(Clone, Debug)]
pub struct XorShiftRng {
    state: u64,
}

impl XorShiftRng {
    /// Seed the generator; a zero seed is remapped (xorshift cannot hold
    /// state 0).
    pub fn new(seed: u64) -> Self {
        XorShiftRng { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed } }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`.
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// Signed 8-bit value in `[lo, hi]` inclusive — the synthetic-weight
    /// generator used for quantized tensors.
    pub fn next_i8_in(&mut self, lo: i8, hi: i8) -> i8 {
        let span = (hi as i64 - lo as i64 + 1) as u64;
        (lo as i64 + self.next_below(span) as i64) as i8
    }

    /// Fill a buffer with int8 values in `[lo, hi]`.
    pub fn fill_i8(&mut self, buf: &mut [i8], lo: i8, hi: i8) {
        for v in buf.iter_mut() {
            *v = self.next_i8_in(lo, hi);
        }
    }

    /// Vector of int8 values in `[lo, hi]`.
    pub fn vec_i8(&mut self, n: usize, lo: i8, hi: i8) -> Vec<i8> {
        (0..n).map(|_| self.next_i8_in(lo, hi)).collect()
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}
