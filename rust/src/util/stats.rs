//! Tiny statistics helper backing the `harness = false` bench binaries
//! (criterion is unavailable in the offline vendor set — see DESIGN.md §2).

use std::time::{Duration, Instant};

/// Collects wall-clock samples of a closure and reports robust summary
/// statistics (median / mean / min / p95).
#[derive(Clone, Debug, Default)]
pub struct BenchStats {
    samples_ns: Vec<u128>,
}

impl BenchStats {
    /// Run `f` once for warmup, then `iters` timed iterations.
    pub fn measure<F: FnMut()>(iters: usize, mut f: F) -> Self {
        f(); // warmup
        let mut samples_ns = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos());
        }
        let mut s = BenchStats { samples_ns };
        s.samples_ns.sort_unstable();
        s
    }

    /// Record a pre-measured sample (nanoseconds).
    pub fn push_ns(&mut self, ns: u128) {
        self.samples_ns.push(ns);
        self.samples_ns.sort_unstable();
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.samples_ns.len()
    }

    /// Median sample.
    pub fn median(&self) -> Duration {
        if self.samples_ns.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.samples_ns[self.samples_ns.len() / 2] as u64)
    }

    /// Arithmetic mean.
    pub fn mean(&self) -> Duration {
        if self.samples_ns.is_empty() {
            return Duration::ZERO;
        }
        let total: u128 = self.samples_ns.iter().sum();
        Duration::from_nanos((total / self.samples_ns.len() as u128) as u64)
    }

    /// Fastest sample.
    pub fn min(&self) -> Duration {
        self.samples_ns.first().map(|&n| Duration::from_nanos(n as u64)).unwrap_or_default()
    }

    /// 95th-percentile sample.
    pub fn p95(&self) -> Duration {
        if self.samples_ns.is_empty() {
            return Duration::ZERO;
        }
        let idx = ((self.samples_ns.len() as f64) * 0.95).ceil() as usize - 1;
        Duration::from_nanos(self.samples_ns[idx.min(self.samples_ns.len() - 1)] as u64)
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "median {:>10.3?}  mean {:>10.3?}  min {:>10.3?}  p95 {:>10.3?}  (n={})",
            self.median(),
            self.mean(),
            self.min(),
            self.p95(),
            self.count()
        )
    }
}
