//! Tiny statistics helper backing the `harness = false` bench binaries
//! (criterion is unavailable in the offline vendor set — see DESIGN.md §2).

use std::time::{Duration, Instant};

/// Fractional-rank decomposition of percentile `p` ∈ [0, 1] over `n`
/// sorted samples: the NIST / numpy `linear` method places percentile
/// `p` at rank `p * (n - 1)` and interpolates between the two closest
/// ranks. Returns `(lo, hi, frac)` with the interpolated value being
/// `sample[lo] + (sample[hi] - sample[lo]) * frac`, or `None` with no
/// samples; with one sample every percentile is that sample
/// (`lo == hi == 0`). Out-of-range `p` clamps.
///
/// This is the **one** percentile implementation in the codebase:
/// [`BenchStats::percentile`] and the serving-layer latency reports
/// ([`crate::exec::serve`]) both delegate here, so the bench harness
/// and the serving engine can never disagree about what "p99" means.
pub fn percentile_rank(n: usize, p: f64) -> Option<(usize, usize, f64)> {
    if n == 0 {
        return None;
    }
    let p = p.clamp(0.0, 1.0);
    let rank = p * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = (rank.ceil() as usize).min(n - 1);
    Some((lo, hi, rank - lo as f64))
}

/// Interpolating percentile over pre-sorted ascending `f64` samples
/// (see [`percentile_rank`]). Returns zero with no samples.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    match percentile_rank(sorted.len(), p) {
        None => 0.0,
        Some((lo, hi, frac)) => {
            let a = sorted[lo];
            let b = sorted[hi];
            a + (b - a) * frac
        }
    }
}

/// Collects wall-clock samples of a closure and reports robust summary
/// statistics (median / mean / min / p95 / p99).
///
/// Samples are kept sorted by insertion (binary search + shift, O(n)
/// per push) so every percentile query is O(1) — the previous
/// implementation re-sorted the whole vector on every `push_ns`.
#[derive(Clone, Debug, Default)]
pub struct BenchStats {
    /// Sorted ascending.
    samples_ns: Vec<u128>,
}

impl BenchStats {
    /// Run `f` once for warmup, then `iters` timed iterations.
    pub fn measure<F: FnMut()>(iters: usize, mut f: F) -> Self {
        f(); // warmup
        let mut samples_ns = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos());
        }
        samples_ns.sort_unstable();
        BenchStats { samples_ns }
    }

    /// Record a pre-measured sample (nanoseconds). Inserts in sorted
    /// position — no re-sort.
    pub fn push_ns(&mut self, ns: u128) {
        let idx = self.samples_ns.partition_point(|&x| x <= ns);
        self.samples_ns.insert(idx, ns);
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.samples_ns.len()
    }

    /// Percentile `p` ∈ [0, 1] with linear interpolation between
    /// closest ranks — the shared [`percentile_rank`] decomposition.
    /// Returns zero with no samples; with one sample every percentile
    /// is that sample.
    pub fn percentile(&self, p: f64) -> Duration {
        let Some((lo, hi, frac)) = percentile_rank(self.samples_ns.len(), p) else {
            return Duration::ZERO;
        };
        let a = self.samples_ns[lo] as f64;
        let b = self.samples_ns[hi] as f64;
        Duration::from_nanos((a + (b - a) * frac).round() as u64)
    }

    /// Median sample (p50).
    pub fn median(&self) -> Duration {
        self.percentile(0.5)
    }

    /// Arithmetic mean.
    pub fn mean(&self) -> Duration {
        if self.samples_ns.is_empty() {
            return Duration::ZERO;
        }
        let total: u128 = self.samples_ns.iter().sum();
        Duration::from_nanos((total / self.samples_ns.len() as u128) as u64)
    }

    /// Fastest sample.
    pub fn min(&self) -> Duration {
        self.samples_ns.first().map(|&n| Duration::from_nanos(n as u64)).unwrap_or_default()
    }

    /// 95th-percentile sample.
    pub fn p95(&self) -> Duration {
        self.percentile(0.95)
    }

    /// 99th-percentile sample.
    pub fn p99(&self) -> Duration {
        self.percentile(0.99)
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "p50 {:>10.3?}  mean {:>10.3?}  min {:>10.3?}  p95 {:>10.3?}  p99 {:>10.3?}  (n={})",
            self.median(),
            self.mean(),
            self.min(),
            self.p95(),
            self.p99(),
            self.count()
        )
    }
}
