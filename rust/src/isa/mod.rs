//! The two-level VTA ISA (§2.2, Fig 3).
//!
//! * **CISC level** — four variable-latency instructions (`LOAD`, `GEMM`,
//!   `ALU`, `STORE`, plus the `FINISH` sentinel) encoded in 128 bits,
//!   each carrying four dependence flags used by the hardware's
//!   dataflow execution (§2.3).
//! * **RISC level** — 32-bit micro-ops executed by the compute core
//!   inside a two-level nested loop with affine index generation (§2.5).
//!
//! The encoding deliberately mirrors the published VTA bitfields: the
//! binary form is what the `fetch` module DMA-reads from DRAM, and the
//! encode/decode round-trip is property-tested in `tests.rs`.

mod insn;
mod uop;

pub use insn::{
    AluInsn, AluOpcode, BufferId, DepFlags, GemmInsn, Instruction, IsaError, MemInsn, Opcode,
    INSN_BYTES,
};
pub use uop::{AluUop, GemmUop, Uop, UOP_BYTES};

#[cfg(test)]
mod tests;
