//! CISC instruction formats and their 128-bit binary encoding (Fig 3).

use thiserror::Error;

/// Size of one encoded CISC instruction in bytes.
pub const INSN_BYTES: usize = 16;

/// ISA-level errors (encode range overflow, decode of malformed words).
#[derive(Debug, Error, PartialEq, Eq)]
pub enum IsaError {
    #[error("field {field} value {value} exceeds {bits}-bit encoding")]
    FieldOverflow { field: &'static str, value: u64, bits: u32 },
    #[error("unknown opcode {0}")]
    BadOpcode(u64),
    #[error("unknown memory type {0}")]
    BadBuffer(u64),
    #[error("unknown ALU opcode {0}")]
    BadAluOpcode(u64),
    #[error("instruction stream length {0} is not a multiple of {INSN_BYTES}")]
    BadStreamLength(usize),
}

/// Top-level opcode (3 bits).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Opcode {
    Load = 0,
    Store = 1,
    Gemm = 2,
    Finish = 3,
    Alu = 4,
}

/// On-chip memory targeted by a LOAD/STORE (§2.6 data-specialized SRAMs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BufferId {
    /// Micro-op cache (loaded via the compute module).
    Uop = 0,
    /// Weight buffer (loaded via the load module).
    Wgt = 1,
    /// Input buffer (loaded via the load module).
    Inp = 2,
    /// Register file / accumulator (loaded via the compute module).
    Acc = 3,
    /// Output buffer (written by compute, drained by the store module).
    Out = 4,
}

impl BufferId {
    /// Decode from the 3-bit memory-type field.
    pub fn from_u64(v: u64) -> Result<Self, IsaError> {
        Ok(match v {
            0 => BufferId::Uop,
            1 => BufferId::Wgt,
            2 => BufferId::Inp,
            3 => BufferId::Acc,
            4 => BufferId::Out,
            other => return Err(IsaError::BadBuffer(other)),
        })
    }
}

/// The four dependence flags carried by every instruction (§2.3, Fig 6).
///
/// "prev" / "next" are relative to the executing module's position in the
/// load → compute → store pipeline: e.g. for the compute module,
/// `pop_prev` pops a RAW token from the load module and `push_prev`
/// pushes a WAR token back to it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DepFlags {
    /// Wait for a RAW token from the producer (previous stage).
    pub pop_prev: bool,
    /// Wait for a WAR token from the consumer (next stage).
    pub pop_next: bool,
    /// Signal a WAR token to the producer when done.
    pub push_prev: bool,
    /// Signal a RAW token to the consumer when done.
    pub push_next: bool,
}

impl DepFlags {
    /// No synchronization.
    pub const NONE: DepFlags =
        DepFlags { pop_prev: false, pop_next: false, push_prev: false, push_next: false };

    fn encode(&self) -> u64 {
        (self.pop_prev as u64)
            | (self.pop_next as u64) << 1
            | (self.push_prev as u64) << 2
            | (self.push_next as u64) << 3
    }

    fn decode(v: u64) -> Self {
        DepFlags {
            pop_prev: v & 1 != 0,
            pop_next: v & 2 != 0,
            push_prev: v & 4 != 0,
            push_next: v & 8 != 0,
        }
    }
}

/// LOAD / STORE: 2D strided DMA between DRAM and an SRAM, with dynamic
/// padding on loads (Fig 9). All sizes are in *tiles* (SRAM rows), not
/// bytes: DRAM addresses are tile-granular, matching the hardware's
/// element-width-specialized ports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemInsn {
    pub deps: DepFlags,
    /// Which SRAM this instruction targets.
    pub buffer: BufferId,
    /// Destination (load) / source (store) SRAM index, in tiles.
    pub sram_base: u32,
    /// Source (load) / destination (store) DRAM address, in tiles.
    pub dram_base: u32,
    /// Number of rows of the 2D transfer.
    pub y_size: u16,
    /// Tiles per row.
    pub x_size: u16,
    /// DRAM stride between rows, in tiles.
    pub x_stride: u16,
    /// Zero-padding rows inserted before the payload (load only).
    pub y_pad_top: u8,
    /// Zero-padding rows appended after the payload (load only).
    pub y_pad_bottom: u8,
    /// Zero-padding tiles inserted at the start of each row (load only).
    pub x_pad_left: u8,
    /// Zero-padding tiles appended at the end of each row (load only).
    pub x_pad_right: u8,
}

impl MemInsn {
    /// Total SRAM rows touched, including padding.
    pub fn sram_rows(&self) -> usize {
        self.y_pad_top as usize + self.y_size as usize + self.y_pad_bottom as usize
    }

    /// SRAM tiles per row, including padding.
    pub fn sram_row_tiles(&self) -> usize {
        self.x_pad_left as usize + self.x_size as usize + self.x_pad_right as usize
    }

    /// Total SRAM tiles written (load) or read (store).
    pub fn sram_tiles(&self) -> usize {
        self.sram_rows() * self.sram_row_tiles()
    }

    /// Tiles actually moved over the DRAM port (padding is generated
    /// on-chip and is free — the whole point of Fig 9).
    pub fn dram_tiles(&self) -> usize {
        self.y_size as usize * self.x_size as usize
    }
}

/// GEMM: run a micro-op sequence in a 2-level nested loop on the GEMM
/// core (Fig 7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmInsn {
    pub deps: DepFlags,
    /// Reset the accumulator tiles instead of multiply-accumulating.
    pub reset: bool,
    /// Micro-op cache range `[uop_begin, uop_end)`.
    pub uop_begin: u16,
    pub uop_end: u16,
    /// Outer loop extent.
    pub lp0: u16,
    /// Inner loop extent.
    pub lp1: u16,
    /// Affine index strides added to each uop's base indices.
    pub acc_factor0: u16,
    pub acc_factor1: u16,
    pub inp_factor0: u16,
    pub inp_factor1: u16,
    pub wgt_factor0: u16,
    pub wgt_factor1: u16,
}

impl GemmInsn {
    /// Number of micro-op executions (= GEMM-core busy cycles, Fig 7:
    /// "one matrix multiplication per cycle").
    pub fn uop_executions(&self) -> u64 {
        self.lp0 as u64 * self.lp1 as u64 * (self.uop_end.saturating_sub(self.uop_begin)) as u64
    }
}

/// Tensor-ALU opcodes (Fig 8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AluOpcode {
    /// Element-wise minimum.
    Min = 0,
    /// Element-wise maximum (ReLU = max with 0 immediate).
    Max = 1,
    /// Element-wise addition (residual connections, bias).
    Add = 2,
    /// Arithmetic shift right (fixed-point requantization).
    Shr = 3,
    /// Logical shift left.
    Shl = 4,
    /// Element-wise multiply (scaling; extension over the minimal set).
    Mul = 5,
    /// Fused requantization: `clamp(a >> imm, -128, 127)` — an extended
    /// ALU operator (§2.5: the operator range "can be extended for
    /// higher operator coverage"); replaces the SHR/MAX/MIN triple on
    /// the requant epilogue, cutting its initiation count 3x.
    Rq = 6,
    /// Fused requantization with ReLU: `clamp(a >> imm, 0, 127)`.
    RqRelu = 7,
}

impl AluOpcode {
    /// Decode from the 3-bit field.
    pub fn from_u64(v: u64) -> Result<Self, IsaError> {
        Ok(match v {
            0 => AluOpcode::Min,
            1 => AluOpcode::Max,
            2 => AluOpcode::Add,
            3 => AluOpcode::Shr,
            4 => AluOpcode::Shl,
            5 => AluOpcode::Mul,
            6 => AluOpcode::Rq,
            7 => AluOpcode::RqRelu,
            other => return Err(IsaError::BadAluOpcode(other)),
        })
    }

    /// Apply to 32-bit accumulator lanes.
    #[inline(always)]
    pub fn apply(&self, a: i32, b: i32) -> i32 {
        match self {
            AluOpcode::Min => a.min(b),
            AluOpcode::Max => a.max(b),
            AluOpcode::Add => a.wrapping_add(b),
            AluOpcode::Shr => a >> (b & 31),
            AluOpcode::Shl => ((a as u32) << (b & 31) as u32) as i32,
            AluOpcode::Mul => a.wrapping_mul(b),
            AluOpcode::Rq => (a >> (b & 31)).clamp(-128, 127),
            AluOpcode::RqRelu => (a >> (b & 31)).clamp(0, 127),
        }
    }
}

/// ALU: run a micro-op sequence on the tensor ALU (Fig 8). Operates on
/// register-file tiles; the second operand is either another tile
/// (tensor-tensor) or an immediate broadcast (tensor-scalar).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AluInsn {
    pub deps: DepFlags,
    pub op: AluOpcode,
    /// Use `imm` instead of a second register-file operand.
    pub use_imm: bool,
    /// Immediate operand (sign-extended 16-bit).
    pub imm: i16,
    /// Micro-op cache range `[uop_begin, uop_end)`.
    pub uop_begin: u16,
    pub uop_end: u16,
    /// Outer loop extent.
    pub lp0: u16,
    /// Inner loop extent.
    pub lp1: u16,
    /// Affine strides for destination and source register-file indices.
    pub dst_factor0: u16,
    pub dst_factor1: u16,
    pub src_factor0: u16,
    pub src_factor1: u16,
}

impl AluInsn {
    /// Number of micro-op executions.
    pub fn uop_executions(&self) -> u64 {
        self.lp0 as u64 * self.lp1 as u64 * (self.uop_end.saturating_sub(self.uop_begin)) as u64
    }
}

/// A decoded VTA CISC instruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Instruction {
    Load(MemInsn),
    Store(MemInsn),
    Gemm(GemmInsn),
    Alu(AluInsn),
    /// End-of-stream sentinel; raises the done flag (§3.2 VTASynchronize).
    Finish(DepFlags),
}

impl Instruction {
    /// The instruction's dependence flags.
    pub fn deps(&self) -> DepFlags {
        match self {
            Instruction::Load(m) | Instruction::Store(m) => m.deps,
            Instruction::Gemm(g) => g.deps,
            Instruction::Alu(a) => a.deps,
            Instruction::Finish(d) => *d,
        }
    }

    /// Mutable access to the dependence flags (used by the runtime's
    /// dependence push/pop API, §3.2).
    pub fn deps_mut(&mut self) -> &mut DepFlags {
        match self {
            Instruction::Load(m) | Instruction::Store(m) => &mut m.deps,
            Instruction::Gemm(g) => &mut g.deps,
            Instruction::Alu(a) => &mut a.deps,
            Instruction::Finish(d) => d,
        }
    }

    /// Opcode of this instruction.
    pub fn opcode(&self) -> Opcode {
        match self {
            Instruction::Load(_) => Opcode::Load,
            Instruction::Store(_) => Opcode::Store,
            Instruction::Gemm(_) => Opcode::Gemm,
            Instruction::Alu(_) => Opcode::Alu,
            Instruction::Finish(_) => Opcode::Finish,
        }
    }

    /// Short mnemonic used in traces and disassembly.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Instruction::Load(m) => match m.buffer {
                BufferId::Uop => "LOAD.UOP",
                BufferId::Wgt => "LOAD.WGT",
                BufferId::Inp => "LOAD.INP",
                BufferId::Acc => "LOAD.ACC",
                BufferId::Out => "LOAD.OUT",
            },
            Instruction::Store(_) => "STORE",
            Instruction::Gemm(g) if g.reset => "GEMM.RST",
            Instruction::Gemm(_) => "GEMM",
            Instruction::Alu(_) => "ALU",
            Instruction::Finish(_) => "FINISH",
        }
    }
}

// ---------------------------------------------------------------------
// 128-bit binary encoding.
//
// Word 0 (low 64 bits) always starts with: opcode[2:0], dep flags[6:3].
// The remaining fields are packed per-format below; a `BitWriter` keeps
// the packing explicit and range-checked.
// ---------------------------------------------------------------------

struct BitWriter {
    words: [u64; 2],
    pos: u32,
}

impl BitWriter {
    fn new() -> Self {
        BitWriter { words: [0, 0], pos: 0 }
    }

    fn put(&mut self, field: &'static str, value: u64, bits: u32) -> Result<(), IsaError> {
        debug_assert!(bits <= 64);
        if bits < 64 && value >= 1u64 << bits {
            return Err(IsaError::FieldOverflow { field, value, bits });
        }
        let mut remaining = bits;
        let mut v = value;
        while remaining > 0 {
            let word = (self.pos / 64) as usize;
            let off = self.pos % 64;
            let take = remaining.min(64 - off);
            debug_assert!(word < 2, "encoding overflowed 128 bits");
            self.words[word] |= (v & mask(take)) << off;
            v >>= take;
            self.pos += take;
            remaining -= take;
        }
        Ok(())
    }

    /// Skip to the start of word 1.
    fn align_word1(&mut self) {
        debug_assert!(self.pos <= 64);
        self.pos = 64;
    }
}

struct BitReader {
    words: [u64; 2],
    pos: u32,
}

impl BitReader {
    fn new(words: [u64; 2]) -> Self {
        BitReader { words, pos: 0 }
    }

    fn get(&mut self, bits: u32) -> u64 {
        let mut out = 0u64;
        let mut got = 0u32;
        let mut remaining = bits;
        while remaining > 0 {
            let word = (self.pos / 64) as usize;
            let off = self.pos % 64;
            let take = remaining.min(64 - off);
            let piece = (self.words[word] >> off) & mask(take);
            out |= piece << got;
            got += take;
            self.pos += take;
            remaining -= take;
        }
        out
    }

    fn align_word1(&mut self) {
        self.pos = 64;
    }
}

#[inline]
fn mask(bits: u32) -> u64 {
    if bits >= 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

impl Instruction {
    /// Encode to the 128-bit binary format.
    pub fn encode(&self) -> Result<[u64; 2], IsaError> {
        let mut w = BitWriter::new();
        w.put("opcode", self.opcode() as u64, 3)?;
        w.put("deps", self.deps().encode(), 4)?;
        match self {
            Instruction::Load(m) | Instruction::Store(m) => {
                w.put("buffer", m.buffer as u64, 3)?;
                w.put("sram_base", m.sram_base as u64, 22)?;
                w.put("dram_base", m.dram_base as u64, 32)?;
                w.align_word1();
                w.put("y_size", m.y_size as u64, 16)?;
                w.put("x_size", m.x_size as u64, 16)?;
                w.put("x_stride", m.x_stride as u64, 16)?;
                w.put("y_pad_top", m.y_pad_top as u64, 4)?;
                w.put("y_pad_bottom", m.y_pad_bottom as u64, 4)?;
                w.put("x_pad_left", m.x_pad_left as u64, 4)?;
                w.put("x_pad_right", m.x_pad_right as u64, 4)?;
            }
            Instruction::Gemm(g) => {
                w.put("reset", g.reset as u64, 1)?;
                w.put("uop_begin", g.uop_begin as u64, 14)?;
                w.put("uop_end", g.uop_end as u64, 14)?;
                w.put("lp0", g.lp0 as u64, 14)?;
                w.put("lp1", g.lp1 as u64, 14)?;
                w.align_word1();
                w.put("acc_factor0", g.acc_factor0 as u64, 11)?;
                w.put("acc_factor1", g.acc_factor1 as u64, 11)?;
                w.put("inp_factor0", g.inp_factor0 as u64, 11)?;
                w.put("inp_factor1", g.inp_factor1 as u64, 11)?;
                w.put("wgt_factor0", g.wgt_factor0 as u64, 10)?;
                w.put("wgt_factor1", g.wgt_factor1 as u64, 10)?;
            }
            Instruction::Alu(a) => {
                w.put("reset", 0, 1)?;
                w.put("uop_begin", a.uop_begin as u64, 14)?;
                w.put("uop_end", a.uop_end as u64, 14)?;
                w.put("lp0", a.lp0 as u64, 14)?;
                w.put("lp1", a.lp1 as u64, 14)?;
                w.align_word1();
                w.put("dst_factor0", a.dst_factor0 as u64, 11)?;
                w.put("dst_factor1", a.dst_factor1 as u64, 11)?;
                w.put("src_factor0", a.src_factor0 as u64, 11)?;
                w.put("src_factor1", a.src_factor1 as u64, 11)?;
                w.put("alu_opcode", a.op as u64, 3)?;
                w.put("use_imm", a.use_imm as u64, 1)?;
                w.put("imm", a.imm as u16 as u64, 16)?;
            }
            Instruction::Finish(_) => {}
        }
        Ok(w.words)
    }

    /// Decode from the 128-bit binary format.
    pub fn decode(words: [u64; 2]) -> Result<Self, IsaError> {
        let mut r = BitReader::new(words);
        let opcode = r.get(3);
        let deps = DepFlags::decode(r.get(4));
        match opcode {
            0 | 1 => {
                let buffer = BufferId::from_u64(r.get(3))?;
                let sram_base = r.get(22) as u32;
                let dram_base = r.get(32) as u32;
                r.align_word1();
                let m = MemInsn {
                    deps,
                    buffer,
                    sram_base,
                    dram_base,
                    y_size: r.get(16) as u16,
                    x_size: r.get(16) as u16,
                    x_stride: r.get(16) as u16,
                    y_pad_top: r.get(4) as u8,
                    y_pad_bottom: r.get(4) as u8,
                    x_pad_left: r.get(4) as u8,
                    x_pad_right: r.get(4) as u8,
                };
                Ok(if opcode == 0 { Instruction::Load(m) } else { Instruction::Store(m) })
            }
            2 => {
                let reset = r.get(1) != 0;
                let uop_begin = r.get(14) as u16;
                let uop_end = r.get(14) as u16;
                let lp0 = r.get(14) as u16;
                let lp1 = r.get(14) as u16;
                r.align_word1();
                Ok(Instruction::Gemm(GemmInsn {
                    deps,
                    reset,
                    uop_begin,
                    uop_end,
                    lp0,
                    lp1,
                    acc_factor0: r.get(11) as u16,
                    acc_factor1: r.get(11) as u16,
                    inp_factor0: r.get(11) as u16,
                    inp_factor1: r.get(11) as u16,
                    wgt_factor0: r.get(10) as u16,
                    wgt_factor1: r.get(10) as u16,
                }))
            }
            3 => Ok(Instruction::Finish(deps)),
            4 => {
                let _reset = r.get(1);
                let uop_begin = r.get(14) as u16;
                let uop_end = r.get(14) as u16;
                let lp0 = r.get(14) as u16;
                let lp1 = r.get(14) as u16;
                r.align_word1();
                let dst_factor0 = r.get(11) as u16;
                let dst_factor1 = r.get(11) as u16;
                let src_factor0 = r.get(11) as u16;
                let src_factor1 = r.get(11) as u16;
                let op = AluOpcode::from_u64(r.get(3))?;
                let use_imm = r.get(1) != 0;
                let imm = r.get(16) as u16 as i16;
                Ok(Instruction::Alu(AluInsn {
                    deps,
                    op,
                    use_imm,
                    imm,
                    uop_begin,
                    uop_end,
                    lp0,
                    lp1,
                    dst_factor0,
                    dst_factor1,
                    src_factor0,
                    src_factor1,
                }))
            }
            other => Err(IsaError::BadOpcode(other)),
        }
    }

    /// Encode a full instruction stream to bytes (the DRAM image the
    /// fetch module reads).
    pub fn encode_stream(insns: &[Instruction]) -> Result<Vec<u8>, IsaError> {
        let mut out = Vec::with_capacity(insns.len() * INSN_BYTES);
        for insn in insns {
            let words = insn.encode()?;
            out.extend_from_slice(&words[0].to_le_bytes());
            out.extend_from_slice(&words[1].to_le_bytes());
        }
        Ok(out)
    }

    /// Decode a byte stream back into instructions.
    pub fn decode_stream(bytes: &[u8]) -> Result<Vec<Instruction>, IsaError> {
        if bytes.len() % INSN_BYTES != 0 {
            return Err(IsaError::BadStreamLength(bytes.len()));
        }
        bytes
            .chunks_exact(INSN_BYTES)
            .map(|c| {
                let w0 = u64::from_le_bytes(c[0..8].try_into().unwrap());
                let w1 = u64::from_le_bytes(c[8..16].try_into().unwrap());
                Instruction::decode([w0, w1])
            })
            .collect()
    }
}
