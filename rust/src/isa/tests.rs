use super::*;
use crate::util::XorShiftRng;

fn sample_mem(deps: DepFlags) -> MemInsn {
    MemInsn {
        deps,
        buffer: BufferId::Inp,
        sram_base: 0x1234,
        dram_base: 0xDEADBEE,
        y_size: 14,
        x_size: 14,
        x_stride: 16,
        y_pad_top: 1,
        y_pad_bottom: 1,
        x_pad_left: 1,
        x_pad_right: 1,
    }
}

#[test]
fn load_store_roundtrip() {
    for buffer in [BufferId::Uop, BufferId::Wgt, BufferId::Inp, BufferId::Acc, BufferId::Out] {
        let mut m = sample_mem(DepFlags { pop_prev: true, ..DepFlags::NONE });
        m.buffer = buffer;
        for insn in [Instruction::Load(m), Instruction::Store(m)] {
            let enc = insn.encode().unwrap();
            assert_eq!(Instruction::decode(enc).unwrap(), insn);
        }
    }
}

#[test]
fn gemm_roundtrip() {
    let g = GemmInsn {
        deps: DepFlags { pop_prev: true, push_next: true, ..DepFlags::NONE },
        reset: true,
        uop_begin: 3,
        uop_end: 130,
        lp0: 14,
        lp1: 16,
        acc_factor0: 16,
        acc_factor1: 1,
        inp_factor0: 14,
        inp_factor1: 1,
        wgt_factor0: 0,
        wgt_factor1: 9,
    };
    let insn = Instruction::Gemm(g);
    assert_eq!(Instruction::decode(insn.encode().unwrap()).unwrap(), insn);
}

#[test]
fn alu_roundtrip_with_negative_imm() {
    let a = AluInsn {
        deps: DepFlags::NONE,
        op: AluOpcode::Shr,
        use_imm: true,
        imm: -42,
        uop_begin: 0,
        uop_end: 7,
        lp0: 2,
        lp1: 3,
        dst_factor0: 4,
        dst_factor1: 1,
        src_factor0: 4,
        src_factor1: 1,
    };
    let insn = Instruction::Alu(a);
    let dec = Instruction::decode(insn.encode().unwrap()).unwrap();
    assert_eq!(dec, insn);
    if let Instruction::Alu(d) = dec {
        assert_eq!(d.imm, -42);
    }
}

#[test]
fn finish_roundtrip() {
    let insn = Instruction::Finish(DepFlags { pop_prev: true, pop_next: true, ..DepFlags::NONE });
    assert_eq!(Instruction::decode(insn.encode().unwrap()).unwrap(), insn);
}

#[test]
fn encode_rejects_overflow() {
    let mut m = sample_mem(DepFlags::NONE);
    m.sram_base = 1 << 22; // 22-bit field
    assert!(matches!(
        Instruction::Load(m).encode(),
        Err(IsaError::FieldOverflow { field: "sram_base", .. })
    ));

    let g = GemmInsn {
        deps: DepFlags::NONE,
        reset: false,
        uop_begin: 0,
        uop_end: 1 << 14,
        lp0: 1,
        lp1: 1,
        acc_factor0: 0,
        acc_factor1: 0,
        inp_factor0: 0,
        inp_factor1: 0,
        wgt_factor0: 0,
        wgt_factor1: 0,
    };
    assert!(Instruction::Gemm(g).encode().is_err());
}

#[test]
fn decode_rejects_bad_opcode() {
    // opcode 7 is undefined
    assert!(matches!(Instruction::decode([7, 0]), Err(IsaError::BadOpcode(7))));
    // opcode LOAD with memory type 6 is undefined
    assert!(matches!(Instruction::decode([0 | (6 << 7), 0]), Err(IsaError::BadBuffer(6))));
}

#[test]
fn stream_roundtrip_and_length_check() {
    let insns = vec![
        Instruction::Load(sample_mem(DepFlags::NONE)),
        Instruction::Finish(DepFlags::NONE),
    ];
    let bytes = Instruction::encode_stream(&insns).unwrap();
    assert_eq!(bytes.len(), 2 * INSN_BYTES);
    assert_eq!(Instruction::decode_stream(&bytes).unwrap(), insns);
    assert!(matches!(
        Instruction::decode_stream(&bytes[..INSN_BYTES + 3]),
        Err(IsaError::BadStreamLength(_))
    ));
}

#[test]
fn uop_roundtrips() {
    let g = GemmUop { acc_idx: 2047, inp_idx: 1023, wgt_idx: 511 };
    let w = Uop::Gemm(g).encode().unwrap();
    assert_eq!(Uop::decode_gemm(w), g);

    let a = AluUop { dst_idx: 100, src_idx: 200 };
    let w = Uop::Alu(a).encode().unwrap();
    assert_eq!(Uop::decode_alu(w), a);
}

#[test]
fn uop_encode_rejects_overflow() {
    assert!(Uop::Gemm(GemmUop { acc_idx: 2048, inp_idx: 0, wgt_idx: 0 }).encode().is_err());
    assert!(Uop::Gemm(GemmUop { acc_idx: 0, inp_idx: 0, wgt_idx: 1024 }).encode().is_err());
    assert!(Uop::Alu(AluUop { dst_idx: 4096, src_idx: 0 }).encode().is_err());
}

/// Property test: randomized instructions round-trip bit-exactly through
/// the 128-bit encoding.
#[test]
fn random_instruction_roundtrip_property() {
    let mut rng = XorShiftRng::new(0xC0FFEE);
    for _ in 0..2000 {
        let insn = random_insn(&mut rng);
        let enc = insn.encode().unwrap();
        let dec = Instruction::decode(enc).unwrap();
        assert_eq!(dec, insn, "roundtrip mismatch for {insn:?}");
    }
}

fn random_deps(rng: &mut XorShiftRng) -> DepFlags {
    DepFlags {
        pop_prev: rng.next_below(2) == 1,
        pop_next: rng.next_below(2) == 1,
        push_prev: rng.next_below(2) == 1,
        push_next: rng.next_below(2) == 1,
    }
}

fn random_insn(rng: &mut XorShiftRng) -> Instruction {
    match rng.next_below(5) {
        0 | 1 => {
            let buffer = match rng.next_below(5) {
                0 => BufferId::Uop,
                1 => BufferId::Wgt,
                2 => BufferId::Inp,
                3 => BufferId::Acc,
                _ => BufferId::Out,
            };
            let m = MemInsn {
                deps: random_deps(rng),
                buffer,
                sram_base: rng.next_below(1 << 22) as u32,
                dram_base: rng.next_below(1 << 32) as u32,
                y_size: rng.next_below(1 << 16) as u16,
                x_size: rng.next_below(1 << 16) as u16,
                x_stride: rng.next_below(1 << 16) as u16,
                y_pad_top: rng.next_below(16) as u8,
                y_pad_bottom: rng.next_below(16) as u8,
                x_pad_left: rng.next_below(16) as u8,
                x_pad_right: rng.next_below(16) as u8,
            };
            if rng.next_below(2) == 0 {
                Instruction::Load(m)
            } else {
                Instruction::Store(m)
            }
        }
        2 => Instruction::Gemm(GemmInsn {
            deps: random_deps(rng),
            reset: rng.next_below(2) == 1,
            uop_begin: rng.next_below(1 << 14) as u16,
            uop_end: rng.next_below(1 << 14) as u16,
            lp0: rng.next_below(1 << 14) as u16,
            lp1: rng.next_below(1 << 14) as u16,
            acc_factor0: rng.next_below(1 << 11) as u16,
            acc_factor1: rng.next_below(1 << 11) as u16,
            inp_factor0: rng.next_below(1 << 11) as u16,
            inp_factor1: rng.next_below(1 << 11) as u16,
            wgt_factor0: rng.next_below(1 << 10) as u16,
            wgt_factor1: rng.next_below(1 << 10) as u16,
        }),
        3 => Instruction::Finish(random_deps(rng)),
        _ => Instruction::Alu(AluInsn {
            deps: random_deps(rng),
            op: AluOpcode::from_u64(rng.next_below(8)).unwrap(),
            use_imm: rng.next_below(2) == 1,
            imm: rng.next_u64() as i16,
            uop_begin: rng.next_below(1 << 14) as u16,
            uop_end: rng.next_below(1 << 14) as u16,
            lp0: rng.next_below(1 << 14) as u16,
            lp1: rng.next_below(1 << 14) as u16,
            dst_factor0: rng.next_below(1 << 11) as u16,
            dst_factor1: rng.next_below(1 << 11) as u16,
            src_factor0: rng.next_below(1 << 11) as u16,
            src_factor1: rng.next_below(1 << 11) as u16,
        }),
    }
}

/// Property test: randomized micro-ops (both interpretations) round-trip
/// bit-exactly through the shared 32-bit encoding.
#[test]
fn random_uop_roundtrip_property() {
    let mut rng = XorShiftRng::new(0x500B);
    for _ in 0..2000 {
        if rng.next_below(2) == 0 {
            let g = GemmUop {
                acc_idx: rng.next_below(1 << 11) as u16,
                inp_idx: rng.next_below(1 << 11) as u16,
                wgt_idx: rng.next_below(1 << 10) as u16,
            };
            let w = Uop::Gemm(g).encode().unwrap();
            assert_eq!(Uop::decode_gemm(w), g, "gemm uop roundtrip for {g:?}");
        } else {
            let a = AluUop {
                dst_idx: rng.next_below(1 << 11) as u16,
                src_idx: rng.next_below(1 << 11) as u16,
            };
            let w = Uop::Alu(a).encode().unwrap();
            assert_eq!(Uop::decode_alu(w), a, "alu uop roundtrip for {a:?}");
        }
    }
}

/// Property test: full encoded streams of randomized instructions
/// round-trip through the byte-level stream codec.
#[test]
fn random_stream_roundtrip_property() {
    let mut rng = XorShiftRng::new(0x57BEA);
    for _ in 0..50 {
        let n = 1 + rng.next_below(40) as usize;
        let insns: Vec<Instruction> = (0..n).map(|_| random_insn(&mut rng)).collect();
        let bytes = Instruction::encode_stream(&insns).unwrap();
        assert_eq!(bytes.len(), n * INSN_BYTES);
        assert_eq!(Instruction::decode_stream(&bytes).unwrap(), insns);
    }
}

/// Property test: pushing any single field past its encoded width must
/// be rejected — randomized over fields and overflow magnitudes.
#[test]
fn random_out_of_range_fields_are_rejected() {
    let mut rng = XorShiftRng::new(0x0F10);
    for _ in 0..500 {
        // Overflow amount: 1 up to a factor of 16 past the field limit.
        let over = |limit: u64, rng: &mut XorShiftRng| limit + 1 + rng.next_below(limit * 15);
        match rng.next_below(6) {
            0 => {
                // MemInsn.sram_base is the only mem field wider than its
                // Rust type's range check: 22 bits inside a u32.
                let mut m = sample_mem(DepFlags::NONE);
                m.sram_base = over((1 << 22) - 1, &mut rng) as u32;
                assert!(
                    matches!(
                        Instruction::Load(m).encode(),
                        Err(IsaError::FieldOverflow { field: "sram_base", .. })
                    ),
                    "sram_base {} must overflow",
                    m.sram_base
                );
            }
            1 => {
                let mut g = GemmInsn {
                    deps: DepFlags::NONE,
                    reset: false,
                    uop_begin: 0,
                    uop_end: 1,
                    lp0: 1,
                    lp1: 1,
                    acc_factor0: 0,
                    acc_factor1: 0,
                    inp_factor0: 0,
                    inp_factor1: 0,
                    wgt_factor0: 0,
                    wgt_factor1: 0,
                };
                // 14-bit loop fields live in u16: overflow range is
                // [1 << 14, u16::MAX].
                let v = (1u64 << 14) + rng.next_below((1 << 16) - (1 << 14));
                match rng.next_below(4) {
                    0 => g.uop_begin = v as u16,
                    1 => g.uop_end = v as u16,
                    2 => g.lp0 = v as u16,
                    _ => g.lp1 = v as u16,
                }
                assert!(Instruction::Gemm(g).encode().is_err(), "14-bit field {v} must overflow");
            }
            2 => {
                let mut g = GemmInsn {
                    deps: DepFlags::NONE,
                    reset: false,
                    uop_begin: 0,
                    uop_end: 1,
                    lp0: 1,
                    lp1: 1,
                    acc_factor0: 0,
                    acc_factor1: 0,
                    inp_factor0: 0,
                    inp_factor1: 0,
                    wgt_factor0: 0,
                    wgt_factor1: 0,
                };
                // 11-bit acc/inp and 10-bit wgt factors.
                match rng.next_below(3) {
                    0 => g.acc_factor0 = ((1u64 << 11) + rng.next_below((1 << 16) - (1 << 11))) as u16,
                    1 => g.inp_factor1 = ((1u64 << 11) + rng.next_below((1 << 16) - (1 << 11))) as u16,
                    _ => g.wgt_factor0 = ((1u64 << 10) + rng.next_below((1 << 16) - (1 << 10))) as u16,
                }
                assert!(Instruction::Gemm(g).encode().is_err());
            }
            3 => {
                let mut a = AluInsn {
                    deps: DepFlags::NONE,
                    op: AluOpcode::Add,
                    use_imm: true,
                    imm: 0,
                    uop_begin: 0,
                    uop_end: 1,
                    lp0: 1,
                    lp1: 1,
                    dst_factor0: 0,
                    dst_factor1: 0,
                    src_factor0: 0,
                    src_factor1: 0,
                };
                let v = ((1u64 << 11) + rng.next_below((1 << 16) - (1 << 11))) as u16;
                match rng.next_below(4) {
                    0 => a.dst_factor0 = v,
                    1 => a.dst_factor1 = v,
                    2 => a.src_factor0 = v,
                    _ => a.src_factor1 = v,
                }
                assert!(Instruction::Alu(a).encode().is_err(), "11-bit ALU factor {v} must overflow");
            }
            4 => {
                // GEMM uop index fields: 11/11/10 bits.
                let bad = match rng.next_below(3) {
                    0 => GemmUop {
                        acc_idx: ((1u64 << 11) + rng.next_below((1 << 16) - (1 << 11))) as u16,
                        inp_idx: 0,
                        wgt_idx: 0,
                    },
                    1 => GemmUop {
                        acc_idx: 0,
                        inp_idx: ((1u64 << 11) + rng.next_below((1 << 16) - (1 << 11))) as u16,
                        wgt_idx: 0,
                    },
                    _ => GemmUop {
                        acc_idx: 0,
                        inp_idx: 0,
                        wgt_idx: ((1u64 << 10) + rng.next_below((1 << 16) - (1 << 10))) as u16,
                    },
                };
                assert!(Uop::Gemm(bad).encode().is_err(), "uop {bad:?} must overflow");
            }
            _ => {
                let bad = if rng.next_below(2) == 0 {
                    AluUop {
                        dst_idx: ((1u64 << 11) + rng.next_below((1 << 16) - (1 << 11))) as u16,
                        src_idx: 0,
                    }
                } else {
                    AluUop {
                        dst_idx: 0,
                        src_idx: ((1u64 << 11) + rng.next_below((1 << 16) - (1 << 11))) as u16,
                    }
                };
                assert!(Uop::Alu(bad).encode().is_err(), "uop {bad:?} must overflow");
            }
        }
    }
}

/// Property test: ALU instructions carrying the requant-epilogue
/// opcodes (`Min`, `Shr`) round-trip bit-exactly — opcode, immediate
/// (including negative), and every index/factor field — and the 3-bit
/// opcode field rejects every out-of-range value.
#[test]
fn random_min_shr_alu_roundtrips_and_bad_opcodes_rejected() {
    let mut rng = XorShiftRng::new(0x514B);
    for _ in 0..1000 {
        let op = if rng.next_below(2) == 0 { AluOpcode::Min } else { AluOpcode::Shr };
        let a = AluInsn {
            deps: random_deps(&mut rng),
            op,
            use_imm: rng.next_below(2) == 1,
            imm: rng.next_u64() as i16,
            uop_begin: rng.next_below(1 << 14) as u16,
            uop_end: rng.next_below(1 << 14) as u16,
            lp0: rng.next_below(1 << 14) as u16,
            lp1: rng.next_below(1 << 14) as u16,
            dst_factor0: rng.next_below(1 << 11) as u16,
            dst_factor1: rng.next_below(1 << 11) as u16,
            src_factor0: rng.next_below(1 << 11) as u16,
            src_factor1: rng.next_below(1 << 11) as u16,
        };
        let insn = Instruction::Alu(a);
        let dec = Instruction::decode(insn.encode().unwrap()).unwrap();
        assert_eq!(dec, insn, "Min/Shr roundtrip mismatch for {a:?}");
        if let Instruction::Alu(d) = dec {
            assert_eq!(d.op, op);
            assert_eq!(d.imm, a.imm);
        }
    }
    // The opcode field is 3 bits: every encodable value decodes, and
    // everything past it is rejected.
    for v in 0..8 {
        assert!(AluOpcode::from_u64(v).is_ok(), "3-bit opcode {v} must decode");
    }
    for _ in 0..100 {
        let v = 8 + rng.next_below(1 << 20);
        assert!(
            matches!(AluOpcode::from_u64(v), Err(IsaError::BadAluOpcode(_))),
            "opcode {v} must be rejected"
        );
    }
}

/// Property test: the `Min` / `Shr` lane semantics agree with a wide
/// (i64) model on random 32-bit operands — min is exact, shift is
/// arithmetic (sign-propagating) with the 5-bit mask the hardware
/// applies.
#[test]
fn random_min_shr_semantics_match_wide_model() {
    let mut rng = XorShiftRng::new(0x514C);
    for _ in 0..2000 {
        let a = rng.next_u64() as u32 as i32;
        let b = rng.next_u64() as u32 as i32;
        assert_eq!(AluOpcode::Min.apply(a, b), a.min(b), "min({a}, {b})");
        let wide = (a as i64) >> ((b & 31) as u32);
        assert_eq!(AluOpcode::Shr.apply(a, b), wide as i32, "shr({a}, {b})");
    }
}

#[test]
fn fused_requant_semantics() {
    assert_eq!(AluOpcode::Rq.apply(1000, 2), 127);
    assert_eq!(AluOpcode::Rq.apply(-1000, 2), -128);
    assert_eq!(AluOpcode::Rq.apply(-64, 4), -4);
    assert_eq!(AluOpcode::RqRelu.apply(-64, 4), 0);
    assert_eq!(AluOpcode::RqRelu.apply(2000, 3), 127);
    assert_eq!(AluOpcode::RqRelu.apply(80, 3), 10);
}

#[test]
fn alu_opcode_semantics() {
    assert_eq!(AluOpcode::Min.apply(3, -5), -5);
    assert_eq!(AluOpcode::Max.apply(3, -5), 3);
    assert_eq!(AluOpcode::Add.apply(i32::MAX, 1), i32::MIN); // wrapping
    assert_eq!(AluOpcode::Shr.apply(-256, 4), -16); // arithmetic
    assert_eq!(AluOpcode::Shl.apply(3, 2), 12);
    assert_eq!(AluOpcode::Mul.apply(-3, 7), -21);
}

#[test]
fn mem_insn_geometry() {
    let m = sample_mem(DepFlags::NONE);
    assert_eq!(m.sram_rows(), 16);
    assert_eq!(m.sram_row_tiles(), 16);
    assert_eq!(m.sram_tiles(), 256);
    assert_eq!(m.dram_tiles(), 196); // padding is free on the DRAM port
}
