//! RISC micro-op level of the ISA (§2.5).
//!
//! A micro-op only carries *base indices*; the hardware's two-level
//! nested loop adds affine offsets (`factor0 * i0 + factor1 * i1`) to
//! each, which is the "compression approach [that] helps reduce the
//! micro-kernel instruction footprint" described in the paper.

use super::IsaError;

/// Size of one encoded micro-op in bytes.
pub const UOP_BYTES: usize = 4;

/// GEMM micro-op: one `acc[dst] += inp[src] x wgt[wgt]` tile operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GemmUop {
    /// Register-file (accumulator) tile index.
    pub acc_idx: u16,
    /// Input-buffer tile index.
    pub inp_idx: u16,
    /// Weight-buffer tile index.
    pub wgt_idx: u16,
}

/// ALU micro-op: one `acc[dst] = op(acc[dst], acc[src] | imm)` tile
/// operation (data-movement pattern only; opcode/imm live in the CISC
/// instruction — Fig 8).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct AluUop {
    /// Destination register-file tile index.
    pub dst_idx: u16,
    /// Source register-file tile index (ignored when `use_imm`).
    pub src_idx: u16,
}

/// A micro-op word. GEMM and ALU uops share the 32-bit encoding:
/// `acc/dst` in bits [10:0], `inp/src` in bits [21:11], `wgt` in
/// bits [31:22] (unused by ALU uops).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Uop {
    Gemm(GemmUop),
    Alu(AluUop),
}

const IDX11_MAX: u16 = (1 << 11) - 1;
const IDX10_MAX: u16 = (1 << 10) - 1;

impl Uop {
    /// Encode to the 32-bit binary form.
    pub fn encode(&self) -> Result<u32, IsaError> {
        match *self {
            Uop::Gemm(u) => {
                check(u.acc_idx, IDX11_MAX, "uop.acc_idx", 11)?;
                check(u.inp_idx, IDX11_MAX, "uop.inp_idx", 11)?;
                check(u.wgt_idx, IDX10_MAX, "uop.wgt_idx", 10)?;
                Ok((u.acc_idx as u32) | (u.inp_idx as u32) << 11 | (u.wgt_idx as u32) << 22)
            }
            Uop::Alu(u) => {
                check(u.dst_idx, IDX11_MAX, "uop.dst_idx", 11)?;
                check(u.src_idx, IDX11_MAX, "uop.src_idx", 11)?;
                Ok((u.dst_idx as u32) | (u.src_idx as u32) << 11)
            }
        }
    }

    /// Decode as a GEMM uop (the executing instruction's opcode decides
    /// the interpretation, so decode is context-driven).
    pub fn decode_gemm(word: u32) -> GemmUop {
        GemmUop {
            acc_idx: (word & 0x7FF) as u16,
            inp_idx: ((word >> 11) & 0x7FF) as u16,
            wgt_idx: ((word >> 22) & 0x3FF) as u16,
        }
    }

    /// Decode as an ALU uop.
    pub fn decode_alu(word: u32) -> AluUop {
        AluUop { dst_idx: (word & 0x7FF) as u16, src_idx: ((word >> 11) & 0x7FF) as u16 }
    }
}

fn check(v: u16, max: u16, field: &'static str, bits: u32) -> Result<(), IsaError> {
    if v > max {
        Err(IsaError::FieldOverflow { field, value: v as u64, bits })
    } else {
        Ok(())
    }
}
