//! Design-space exploration and autotuning — the blueprint's third
//! pillar: "a flow that performs design space exploration to generate
//! a customized hardware architecture and software operator library".
//!
//! Two coupled searches:
//!
//! * **Hardware DSE** ([`space`]) — candidate [`VtaConfig`]s (GEMM
//!   geometry, SRAM depths, ALU width) are sampled under an FPGA
//!   resource model and scored by cycle-accurate simulation on a
//!   workload suite.
//! * **Schedule tuning** ([`tune`]) — per (config, operator), the
//!   tiling factors the planners otherwise pick greedily are searched
//!   by measured cost, yielding a [`ScheduleChoice`] per operator.
//!
//! And one search **over** the first: **fleet allocation** ([`fleet`])
//! enumerates multisets of frontier configs under a fleet-wide
//! resource budget, scored by the cost-routed modeled makespan of
//! mixed traffic, and emits the winning composition as a
//! [`FleetSpec`](crate::exec::serve::fleet::FleetSpec) that
//! `vta serve --fleet` deploys.
//!
//! Winning (config, schedule) pairs persist to a JSON tuning-record
//! store ([`records`]) that the serving engine consults at compile
//! time, so tuned schedules survive restarts and serving traffic
//! automatically runs the tuned plan. The `vta dse` CLI subcommand
//! drives [`run_dse`]; `benches/ablations.rs` replays the found
//! frontier.
//!
//! Search strategy: a budgeted random sweep (two thirds of the budget)
//! followed by greedy refinement (single-axis mutations of the
//! best-so-far). The tuned baseline variant (pynq by default) is
//! always candidate zero, so the frontier never loses to the paper's
//! hand-picked design.
//!
//! Candidates are scored at **pool level**
//! ([`DseOptions::pool_devices`], [`pool_makespan_cycles`]): the
//! objective is the modeled makespan of the suite dispatched
//! least-loaded across N replicas — the same dispatch rule the
//! multi-device serving scheduler uses — which reduces to the classic
//! cycle sum on a one-device pool. `vta dse --devices N` threads the
//! pool size here.

pub mod fleet;
pub mod records;
pub mod space;
pub mod tune;

pub use fleet::{
    interleave_classes, run_fleet_dse, total_budget, FleetComposition, FleetDseOptions,
    FleetDseReport,
};
pub use records::{RecordKey, TuningRecord, TuningRecords};
pub use space::{ConfigSpace, ResourceBudget, ResourceUsage};
pub use tune::{
    eval_conv2d, eval_eltwise, eval_matmul, eval_upsample2x, tune_conv2d, tune_matmul, TuneOutcome,
};

use crate::arch::VtaConfig;
use crate::compiler::{
    config_fingerprint, op_impl, Conv2dParams, EltwiseKind, MatmulParams, Requant, ScheduleChoice,
};
use crate::graph::resnet::table1_params;
use crate::graph::{Graph, Op};
use crate::util::XorShiftRng;
use anyhow::{bail, Context, Result};

/// One benchmark workload candidates are scored on.
#[derive(Clone, Debug)]
pub enum Workload {
    /// A conv2d layer (Table 1 style).
    Conv2d { name: &'static str, p: Conv2dParams },
    /// A dense / fully-connected layer.
    Dense { name: &'static str, p: MatmulParams },
    /// An elementwise tensor-ALU operator over `len` int8 elements.
    Eltwise { name: &'static str, kind: EltwiseKind, len: usize },
    /// Nearest-neighbor 2x upsampling over a `[1, c, h, w]` image (the
    /// style-transfer strided store/copy pass).
    Upsample2x { name: &'static str, c: usize, h: usize, w: usize },
}

impl Workload {
    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Workload::Conv2d { name, .. }
            | Workload::Dense { name, .. }
            | Workload::Eltwise { name, .. }
            | Workload::Upsample2x { name, .. } => name,
        }
    }
}

impl std::fmt::Display for Workload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

const RQ: Requant = Requant { shift: 6, relu: false };

/// A named workload suite for the CLI / CI.
///
/// * `tiny` — seconds-scale suite for smoke tests and CI.
/// * `resnet` — representative ResNet-18 layers (compute-bound 3x3,
///   bandwidth-bound 1x1, the deep C12, the classifier, a residual
///   add).
/// * `style` — the fast-style-transfer pipeline's structurally
///   different mix (stride-2 down-conv, bottleneck residual conv,
///   store-bound upsampling, and the Min/Shr requant-epilogue ops).
pub fn suite(name: &str) -> Result<Vec<Workload>> {
    match name {
        "tiny" => Ok(vec![
            Workload::Conv2d {
                name: "conv3",
                p: Conv2dParams { h: 8, w: 8, ic: 32, oc: 32, k: 3, s: 1, requant: RQ },
            },
            Workload::Conv2d {
                name: "conv1",
                p: Conv2dParams { h: 14, w: 14, ic: 32, oc: 32, k: 1, s: 1, requant: RQ },
            },
            Workload::Dense {
                name: "dense",
                p: MatmulParams { m: 2, k: 64, n: 64, requant: RQ },
            },
            Workload::Eltwise { name: "add", kind: EltwiseKind::AddSat, len: 16 * 1024 },
        ]),
        "resnet" => Ok(vec![
            Workload::Conv2d { name: "C2", p: table1_params(1) },
            Workload::Conv2d { name: "C3", p: table1_params(2) },
            Workload::Conv2d { name: "C6", p: table1_params(5) },
            Workload::Conv2d { name: "C12", p: table1_params(11) },
            Workload::Dense {
                name: "fc",
                p: MatmulParams { m: 1, k: 512, n: 1000, requant: Requant { shift: 7, relu: false } },
            },
            Workload::Eltwise { name: "add", kind: EltwiseKind::AddSat, len: 64 * 56 * 56 },
        ]),
        "style" => Ok(vec![
            Workload::Conv2d {
                name: "down2",
                p: Conv2dParams { h: 16, w: 16, ic: 16, oc: 32, k: 3, s: 2, requant: RQ },
            },
            Workload::Conv2d {
                name: "res",
                p: Conv2dParams { h: 8, w: 8, ic: 32, oc: 32, k: 3, s: 1, requant: RQ },
            },
            Workload::Upsample2x { name: "up", c: 32, h: 8, w: 8 },
            Workload::Eltwise { name: "add", kind: EltwiseKind::AddSat, len: 32 * 8 * 8 },
            Workload::Eltwise { name: "shr", kind: EltwiseKind::ShrImm(1), len: 3 * 32 * 32 },
            Workload::Eltwise { name: "min", kind: EltwiseKind::MinImm(100), len: 3 * 32 * 32 },
        ]),
        other => bail!("unknown workload suite {other:?} (expected tiny|resnet|style)"),
    }
}

/// Search options.
#[derive(Clone, Debug)]
pub struct DseOptions {
    /// The reference variant: scored untuned as the baseline, and
    /// entered tuned as candidate zero (so the frontier never loses to
    /// it). Defaults to the paper's Pynq point; the CLI threads
    /// `--config` here.
    pub baseline: VtaConfig,
    /// Hardware candidates to evaluate (the tuned baseline point is
    /// candidate zero and counts against this).
    pub budget: usize,
    /// Schedule candidates measured per (config, tunable operator).
    pub tune_trials: usize,
    /// Virtual threads the schedules are tuned for, ∈ {1, 2}.
    pub virtual_threads: usize,
    /// PRNG seed (the whole search is deterministic in it).
    pub seed: u64,
    /// Frontier size to keep / report.
    pub top_k: usize,
    /// Replicas in the serving pool the candidates are scored for.
    /// With 1 (the default) the objective is the classic sum of
    /// per-workload cycles; with N the objective is the modeled pool
    /// **makespan** — the suite's workloads dispatched least-loaded
    /// across N replicas ([`pool_makespan_cycles`]) — so candidates
    /// whose one dominant workload would bottleneck a pool rank
    /// accordingly.
    pub pool_devices: usize,
    /// The scoring suite.
    pub workloads: Vec<Workload>,
}

impl DseOptions {
    /// Defaults over a given suite.
    pub fn new(workloads: Vec<Workload>) -> Self {
        DseOptions {
            baseline: VtaConfig::pynq(),
            budget: 16,
            tune_trials: 4,
            virtual_threads: 2,
            seed: 0xD5E,
            top_k: 5,
            pool_devices: 1,
            workloads,
        }
    }
}

/// Modeled pool-level makespan of a set of independent workloads over
/// `devices` identical replicas: longest-processing-time-first greedy
/// assignment (each workload goes to the least-loaded replica), the
/// same least-loaded rule the serving scheduler
/// ([`crate::exec::serve::Scheduler`]) dispatches with. With one
/// device this is exactly the sum; it is always at least the largest
/// single workload and at least the ideal `ceil(sum / devices)`.
pub fn pool_makespan_cycles(cycles: &[u64], devices: usize) -> u64 {
    assert!(devices >= 1, "a pool has at least one device");
    if devices == 1 {
        return cycles.iter().fold(0u64, |a, &c| a.saturating_add(c));
    }
    let mut sorted: Vec<u64> = cycles.to_vec();
    sorted.sort_unstable_by(|a, b| b.cmp(a));
    let mut load = vec![0u64; devices];
    for c in sorted {
        let d = (0..devices).min_by_key(|&d| load[d]).expect("non-empty pool");
        load[d] = load[d].saturating_add(c);
    }
    load.into_iter().max().unwrap_or(0)
}

/// One workload's score under a candidate.
#[derive(Clone, Debug)]
pub struct WorkloadScore {
    pub name: &'static str,
    /// Operator class ("conv2d" / "dense" / "add" / "relu").
    pub kind: &'static str,
    /// Best measured cycles (tuned when a choice is present).
    pub cycles: u64,
    /// Winning tuned schedule (`None` = planner default won or the
    /// operator has no tunable schedule).
    pub choice: Option<ScheduleChoice>,
    /// Tuning-record key material for this operator
    /// ([`crate::compiler::VtaOp::schedule_fingerprint`]); 0 for
    /// operators without tunable schedules.
    pub sched_fp: u64,
}

/// One evaluated hardware candidate.
#[derive(Clone, Debug)]
pub struct CandidateResult {
    pub cfg: VtaConfig,
    pub config_fp: u64,
    pub usage: ResourceUsage,
    pub scores: Vec<WorkloadScore>,
    /// Sum of per-workload cycles (the single-device objective).
    pub total_cycles: u64,
    /// Modeled pool makespan of the suite over
    /// [`DseOptions::pool_devices`] replicas
    /// ([`pool_makespan_cycles`]); equals `total_cycles` when the pool
    /// has one device. **The scalar search objective.**
    pub pool_cycles: u64,
}

/// The search outcome: baseline, frontier, counters.
#[derive(Clone, Debug)]
pub struct DseReport {
    /// The baseline variant ([`DseOptions::baseline`], pynq by
    /// default) with planner-default schedules, untouched by tuning.
    pub baseline: CandidateResult,
    /// Candidate zero — the baseline variant *with* schedule tuning.
    /// Kept outside the frontier truncation so its records always
    /// export: `vta serve` without `--config` runs this variant, and
    /// dropping its schedules whenever k better exotic candidates
    /// exist would make the documented dse-then-serve flow a no-op.
    pub tuned_baseline: Option<CandidateResult>,
    /// Top-k candidates, best (fewest total cycles) first.
    pub frontier: Vec<CandidateResult>,
    /// Virtual threads the search tuned for.
    pub virtual_threads: usize,
    /// Candidate evaluations attempted (incl. infeasible/duplicate).
    pub evaluated: usize,
    /// Candidates that failed to plan on some workload.
    pub infeasible: usize,
}

impl DseReport {
    /// The best candidate found.
    pub fn best(&self) -> &CandidateResult {
        &self.frontier[0]
    }

    /// True when the best candidate beats or matches the baseline —
    /// the `dse-smoke` CI gate. Compared at the pool level (identical
    /// to total cycles on a one-device pool).
    pub fn improved(&self) -> bool {
        self.best().pool_cycles <= self.baseline.pool_cycles
    }

    /// Export the tuned schedules of the frontier **and** the tuned
    /// baseline as a record store, keyed by each candidate's config
    /// fingerprint: `vta serve --config <candidate> --records <file>`
    /// picks up exactly that variant's schedules, and a plain
    /// `vta serve --records <file>` (baseline config) always finds its
    /// own, even when the frontier is full of better exotic variants.
    pub fn export_records(&self) -> TuningRecords {
        let mut store = TuningRecords::new();
        for cand in self.frontier.iter().chain(&self.tuned_baseline) {
            for s in &cand.scores {
                if let Some(choice) = s.choice {
                    store.insert(
                        RecordKey {
                            config_fp: cand.config_fp,
                            virtual_threads: self.virtual_threads,
                            sched_fp: s.sched_fp,
                        },
                        TuningRecord { choice, cycles: s.cycles },
                    );
                }
            }
        }
        store
    }
}

/// Schedule fingerprint of a conv2d layer, as the serving engine will
/// compute it for a graph node with these params (weights excluded by
/// construction).
pub fn conv_sched_fp(p: &Conv2dParams) -> u64 {
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, p.ic, p.h, p.w] }, &[]).expect("input node");
    let c = g.add("conv", Op::Conv2d { p: *p }, &[x]).expect("conv node");
    let node = &g.nodes[c];
    op_impl(&node.op).schedule_fingerprint(node)
}

/// Schedule fingerprint of a dense layer.
pub fn dense_sched_fp(p: &MatmulParams) -> u64 {
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![p.m, p.k] }, &[]).expect("input node");
    let d = g.add("fc", Op::Dense { p: *p }, &[x]).expect("dense node");
    let node = &g.nodes[d];
    op_impl(&node.op).schedule_fingerprint(node)
}

/// Score one hardware candidate on the full suite. `tune` enables the
/// schedule search; the baseline is measured with planner defaults.
/// Returns `None` when any workload fails to plan on this variant.
fn evaluate_candidate(
    cfg: &VtaConfig,
    opts: &DseOptions,
    rng: &mut XorShiftRng,
    tune: bool,
) -> Option<CandidateResult> {
    let vt = opts.virtual_threads;
    let mut scores = Vec::with_capacity(opts.workloads.len());
    let mut total = 0u64;
    for w in &opts.workloads {
        let score = match w {
            Workload::Conv2d { name, p } => {
                let (cycles, choice) = if tune && opts.tune_trials > 0 {
                    let out = tune_conv2d(cfg, p, vt, opts.tune_trials, rng).ok()?;
                    (out.cycles, out.choice)
                } else {
                    (eval_conv2d(cfg, p, vt, None, 17).ok()?, None)
                };
                WorkloadScore {
                    name: *name,
                    kind: "conv2d",
                    cycles,
                    choice,
                    sched_fp: conv_sched_fp(p),
                }
            }
            Workload::Dense { name, p } => {
                let (cycles, choice) = if tune && opts.tune_trials > 0 {
                    let out = tune_matmul(cfg, p, vt, opts.tune_trials, rng).ok()?;
                    (out.cycles, out.choice)
                } else {
                    (eval_matmul(cfg, p, vt, None, 19).ok()?, None)
                };
                WorkloadScore {
                    name: *name,
                    kind: "dense",
                    cycles,
                    choice,
                    sched_fp: dense_sched_fp(p),
                }
            }
            Workload::Eltwise { name, kind, len } => {
                let cycles = eval_eltwise(cfg, *kind, *len, vt, 23).ok()?;
                let kind_name = match kind {
                    EltwiseKind::AddSat => "add",
                    EltwiseKind::Relu => "relu",
                    EltwiseKind::MinImm(_) => "min",
                    EltwiseKind::ShrImm(_) => "shr",
                };
                WorkloadScore { name: *name, kind: kind_name, cycles, choice: None, sched_fp: 0 }
            }
            Workload::Upsample2x { name, c, h, w } => {
                let cycles = eval_upsample2x(cfg, *c, *h, *w, vt, 29).ok()?;
                WorkloadScore { name: *name, kind: "upsample2x", cycles, choice: None, sched_fp: 0 }
            }
        };
        total = total.saturating_add(score.cycles);
        scores.push(score);
    }
    let per_workload: Vec<u64> = scores.iter().map(|s| s.cycles).collect();
    Some(CandidateResult {
        cfg: cfg.clone(),
        config_fp: config_fingerprint(cfg),
        usage: ResourceUsage::of(cfg),
        scores,
        total_cycles: total,
        pool_cycles: pool_makespan_cycles(&per_workload, opts.pool_devices),
    })
}

/// Run the coupled hardware + schedule search.
pub fn run_dse(opts: &DseOptions) -> Result<DseReport> {
    anyhow::ensure!(!opts.workloads.is_empty(), "DSE needs at least one workload");
    anyhow::ensure!(
        opts.virtual_threads == 1 || opts.virtual_threads == 2,
        "1 or 2 virtual threads"
    );
    anyhow::ensure!(opts.budget >= 1, "DSE needs a budget of at least one candidate");
    anyhow::ensure!(opts.pool_devices >= 1, "DSE pools need at least one device");
    let space = ConfigSpace::new();
    let base_cfg = opts.baseline.clone();
    let mut rng = XorShiftRng::new(opts.seed);

    // The untuned baseline point (pynq by default — the paper's
    // design, as every prior layer of this stack runs it).
    let baseline = evaluate_candidate(&base_cfg, opts, &mut rng, false)
        .context("the baseline variant must plan on every workload")?;

    let mut results: Vec<CandidateResult> = Vec::new();
    let mut seen: Vec<u64> = Vec::new();
    let mut infeasible = 0usize;
    let mut evaluated = 0usize;
    let random_phase = 1 + (opts.budget.saturating_sub(1)) * 2 / 3;

    while evaluated < opts.budget {
        let cfg = if evaluated == 0 {
            // Candidate zero: the baseline point with schedule tuning.
            base_cfg.clone()
        } else if evaluated < random_phase {
            space.sample(&mut rng)
        } else {
            // Greedy refine around the best-so-far.
            let best = results
                .iter()
                .min_by_key(|r| r.pool_cycles)
                .map(|r| r.cfg.clone())
                .unwrap_or_else(|| base_cfg.clone());
            space.mutate(&best, &mut rng)
        };
        evaluated += 1;
        let fp = config_fingerprint(&cfg);
        if seen.contains(&fp) {
            continue;
        }
        seen.push(fp);
        match evaluate_candidate(&cfg, opts, &mut rng, true) {
            Some(r) => results.push(r),
            None => infeasible += 1,
        }
    }

    let base_fp = config_fingerprint(&base_cfg);
    let tuned_baseline = results.iter().find(|r| r.config_fp == base_fp).cloned();
    results.sort_by_key(|r| r.pool_cycles);
    results.truncate(opts.top_k.max(1));
    Ok(DseReport {
        baseline,
        tuned_baseline,
        frontier: results,
        virtual_threads: opts.virtual_threads,
        evaluated,
        infeasible,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{CpuBackend, ServingEngine};
    use crate::graph::{partition, PartitionPolicy};
    use crate::util::Tensor;

    fn tiny_opts(budget: usize) -> DseOptions {
        let mut o = DseOptions::new(suite("tiny").unwrap());
        o.budget = budget;
        o.tune_trials = 3;
        o.top_k = 3;
        o
    }

    /// The acceptance gate: even a tiny-budget search matches or beats
    /// the Pynq-default baseline (candidate zero is tuned-Pynq, and
    /// tuning never regresses), and the search is deterministic in its
    /// seed.
    #[test]
    fn tiny_dse_matches_or_beats_the_baseline() {
        let opts = tiny_opts(3);
        let report = run_dse(&opts).unwrap();
        assert!(!report.frontier.is_empty());
        assert!(
            report.improved(),
            "best {} > baseline {}",
            report.best().total_cycles,
            report.baseline.total_cycles
        );
        assert_eq!(report.evaluated, 3);
        // The tuned baseline is tracked outside the frontier, so its
        // records always export (the `vta serve` default-config flow).
        let tb = report.tuned_baseline.as_ref().expect("tuned baseline evaluated");
        assert_eq!(tb.config_fp, config_fingerprint(&VtaConfig::pynq()));
        assert!(tb.total_cycles <= report.baseline.total_cycles);
        let exported = report.export_records();
        for s in tb.scores.iter().filter(|s| s.choice.is_some()) {
            assert_eq!(
                exported.lookup(tb.config_fp, report.virtual_threads, s.sched_fp),
                s.choice,
                "baseline-config record for {} must export",
                s.name
            );
        }
        // Determinism: same seed, same frontier.
        let again = run_dse(&opts).unwrap();
        assert_eq!(again.best().config_fp, report.best().config_fp);
        assert_eq!(again.best().total_cycles, report.best().total_cycles);
    }

    /// Exported records round-trip through JSON and resolve under the
    /// exact keys the serving engine computes.
    #[test]
    fn exported_records_use_serving_engine_keys() {
        let p = Conv2dParams { h: 8, w: 8, ic: 32, oc: 32, k: 3, s: 1, requant: RQ };
        let cfg = VtaConfig::pynq();
        let choice = ScheduleChoice::Conv2d { oc_t: 1, oh_t: 2, ow_t: 8 };
        let report = DseReport {
            baseline: evaluate_candidate(&cfg, &tiny_opts(1), &mut XorShiftRng::new(1), false)
                .unwrap(),
            tuned_baseline: None,
            frontier: vec![CandidateResult {
                cfg: cfg.clone(),
                config_fp: config_fingerprint(&cfg),
                usage: ResourceUsage::of(&cfg),
                scores: vec![WorkloadScore {
                    name: "conv3",
                    kind: "conv2d",
                    cycles: 100,
                    choice: Some(choice),
                    sched_fp: conv_sched_fp(&p),
                }],
                total_cycles: 100,
                pool_cycles: 100,
            }],
            virtual_threads: 2,
            evaluated: 1,
            infeasible: 0,
        };
        let store = TuningRecords::from_json(&report.export_records().to_json()).unwrap();

        // The serving engine computes the same key for a graph node
        // with these params (different weights, same schedule).
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, p.ic, p.h, p.w] }, &[]).unwrap();
        let c = g.add("conv", Op::Conv2d { p }, &[x]).unwrap();
        let node = &g.nodes[c];
        let sfp = op_impl(&node.op).schedule_fingerprint(node);
        assert_eq!(store.lookup(config_fingerprint(&cfg), 2, sfp), Some(choice));
    }

    /// The ISSUE acceptance scenario: a persisted (config, schedule)
    /// record is picked up by a freshly constructed ("restarted")
    /// serving engine — the tuned schedule reaches the compiled plan
    /// and results stay bit-identical to the untuned engine.
    #[test]
    fn restarted_serving_engine_picks_up_tuned_records() {
        let cfg = VtaConfig::pynq();
        let p = Conv2dParams { h: 8, w: 8, ic: 16, oc: 32, k: 3, s: 1, requant: RQ };
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
        let c = g.add("conv", Op::Conv2d { p }, &[x]).unwrap();
        let mut rng = XorShiftRng::new(404);
        g.set_weights(c, Tensor::from_vec(&[32, 16, 3, 3], rng.vec_i8(32 * 16 * 9, -4, 4)).unwrap());
        partition(&mut g, &PartitionPolicy::paper(&cfg));

        let input = Tensor::from_vec(&[1, 16, 8, 8], rng.vec_i8(16 * 64, -8, 8)).unwrap();

        // Untuned engine: the reference behavior.
        let mut plain = ServingEngine::new(&cfg, 64 << 20, CpuBackend::Native, 2, 4);
        let expect = plain.run_one(&g, &input).unwrap().output;
        let key = plain.plan_key(&g, &g.nodes[c]);
        assert_eq!(plain.cached_schedule(&key), None, "untuned plan carries no schedule");

        // Persist a distinctive feasible schedule to disk...
        let choice = ScheduleChoice::Conv2d { oc_t: 1, oh_t: 2, ow_t: 8 };
        assert!(crate::compiler::plan_conv2d_tuned(&cfg, &p, 2, Some(&choice)).is_ok());
        let node = &g.nodes[c];
        let sfp = op_impl(&node.op).schedule_fingerprint(node);
        let mut store = TuningRecords::new();
        store.insert(
            RecordKey { config_fp: config_fingerprint(&cfg), virtual_threads: 2, sched_fp: sfp },
            TuningRecord { choice, cycles: 1 },
        );
        let path = std::env::temp_dir().join("vta_dse_serve_pickup_test.json");
        store.save(&path).unwrap();

        // ...and "restart": a fresh engine loads the store from disk.
        let loaded = TuningRecords::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let mut tuned =
            ServingEngine::with_records(&cfg, 64 << 20, CpuBackend::Native, 2, 4, loaded);
        assert_eq!(tuned.tuned_records(), 1);
        assert_eq!(tuned.tuned_schedule(&g.nodes[c]), Some(choice));
        let r = tuned.run_one(&g, &input).unwrap();
        assert_eq!(r.output, expect, "tuned schedule must not change results");
        assert_eq!(
            tuned.cached_schedule(&key),
            Some(choice),
            "the compiled plan must carry the tuned schedule"
        );
    }

    /// Pool-level scoring: the makespan model is exact on one device,
    /// monotone (weakly) in pool size, never better than the ideal
    /// split, and never hides the dominant workload.
    #[test]
    fn pool_makespan_model_is_sane() {
        let cycles = [700u64, 300, 200, 100, 100];
        let sum: u64 = cycles.iter().sum();
        assert_eq!(pool_makespan_cycles(&cycles, 1), sum);
        // LPT on 2 devices: 700/{300,200,100,100} → max(700, 700) = 700.
        assert_eq!(pool_makespan_cycles(&cycles, 2), 700);
        let mut prev = u64::MAX;
        for devices in 1..=6 {
            let m = pool_makespan_cycles(&cycles, devices);
            assert!(m <= prev, "makespan must not grow with pool size");
            assert!(m >= *cycles.iter().max().unwrap(), "dominant workload bounds below");
            assert!(m >= sum.div_ceil(devices as u64), "ideal split bounds below");
            prev = m;
        }
        // Degenerate cases.
        assert_eq!(pool_makespan_cycles(&[], 3), 0);
        assert_eq!(pool_makespan_cycles(&[42], 4), 42);
    }

    /// `pool_devices` threads into candidate scoring: every evaluated
    /// candidate carries a pool makespan consistent with its
    /// per-workload scores, and a one-device pool reduces to the
    /// classic total.
    #[test]
    fn dse_scores_candidates_at_pool_level() {
        let mut opts = tiny_opts(2);
        opts.pool_devices = 3;
        let report = run_dse(&opts).unwrap();
        for cand in report.frontier.iter().chain([&report.baseline]) {
            let per: Vec<u64> = cand.scores.iter().map(|s| s.cycles).collect();
            assert_eq!(cand.pool_cycles, pool_makespan_cycles(&per, 3));
            assert!(cand.pool_cycles <= cand.total_cycles);
        }
        // The frontier is ranked by the pool objective.
        for pair in report.frontier.windows(2) {
            assert!(
                pair[0].pool_cycles <= pair[1].pool_cycles,
                "frontier must sort by pool makespan"
            );
        }

        let single = run_dse(&tiny_opts(2)).unwrap();
        for cand in single.frontier.iter().chain([&single.baseline]) {
            assert_eq!(cand.pool_cycles, cand.total_cycles, "one-device pool = classic total");
        }
    }
}
