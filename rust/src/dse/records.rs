//! The tuning-record store: best-known schedules found by design-space
//! exploration, persisted as JSON so they survive restarts.
//!
//! A record maps (hardware-config fingerprint, virtual threads,
//! schedule fingerprint) → the best [`ScheduleChoice`] measured for
//! that operator on that variant, plus the simulated cycle count it
//! achieved. The schedule fingerprint
//! ([`crate::compiler::VtaOp::schedule_fingerprint`]) covers operator
//! parameters and output shape but **not** weights, so records tuned
//! on synthetic workloads apply to any serving graph with the same
//! layer shapes.
//!
//! The on-disk format is plain JSON (the offline vendor set has no
//! serde, so [`json`] implements the small subset needed here —
//! objects, arrays, strings, unsigned integers, booleans):
//!
//! ```json
//! {
//!   "version": 1,
//!   "records": [
//!     { "config_fp": 123, "vt": 2, "sched_fp": 456, "cycles": 7890,
//!       "choice": { "op": "conv2d", "oc_t": 2, "oh_t": 7, "ow_t": 28 } }
//!   ]
//! }
//! ```

use crate::compiler::ScheduleChoice;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::fmt::Write as _;
use std::path::Path;

/// Identity of one tuning record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RecordKey {
    /// Hardware variant ([`crate::compiler::config_fingerprint`]).
    pub config_fp: u64,
    /// Virtual-thread count the schedule was tuned for.
    pub virtual_threads: usize,
    /// Operator schedule fingerprint
    /// ([`crate::compiler::VtaOp::schedule_fingerprint`]).
    pub sched_fp: u64,
}

/// One stored tuning result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TuningRecord {
    /// The winning schedule.
    pub choice: ScheduleChoice,
    /// Simulated cycles measured when the record was produced (used to
    /// keep the better record on key collisions).
    pub cycles: u64,
}

/// In-memory store of tuning records, with JSON load/save.
#[derive(Clone, Debug, Default)]
pub struct TuningRecords {
    map: HashMap<RecordKey, TuningRecord>,
}

impl TuningRecords {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of records.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when no records are stored.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// The best-known schedule for this (config, vt, operator) triple.
    pub fn lookup(&self, config_fp: u64, virtual_threads: usize, sched_fp: u64) -> Option<ScheduleChoice> {
        self.map
            .get(&RecordKey { config_fp, virtual_threads, sched_fp })
            .map(|r| r.choice)
    }

    /// Insert a record, keeping the better (fewer-cycle) one on
    /// collision. Returns true when the store changed.
    pub fn insert(&mut self, key: RecordKey, rec: TuningRecord) -> bool {
        match self.map.get(&key) {
            Some(old) if old.cycles <= rec.cycles => false,
            _ => {
                self.map.insert(key, rec);
                true
            }
        }
    }

    /// Merge another store, record by record (better cycles win).
    pub fn merge(&mut self, other: &TuningRecords) {
        for (k, r) in &other.map {
            self.insert(*k, *r);
        }
    }

    /// Iterate over all records.
    pub fn iter(&self) -> impl Iterator<Item = (&RecordKey, &TuningRecord)> {
        self.map.iter()
    }

    /// Serialize to the JSON record format (keys sorted for stable
    /// output).
    pub fn to_json(&self) -> String {
        let mut entries: Vec<(&RecordKey, &TuningRecord)> = self.map.iter().collect();
        entries.sort_by_key(|(k, _)| (k.config_fp, k.virtual_threads, k.sched_fp));
        let mut s = String::new();
        s.push_str("{\n  \"version\": 1,\n  \"records\": [");
        for (i, (k, r)) in entries.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "\n    {{ \"config_fp\": {}, \"vt\": {}, \"sched_fp\": {}, \"cycles\": {}, \"choice\": ",
                k.config_fp, k.virtual_threads, k.sched_fp, r.cycles
            );
            match r.choice {
                ScheduleChoice::Conv2d { oc_t, oh_t, ow_t } => {
                    let _ = write!(
                        s,
                        "{{ \"op\": \"conv2d\", \"oc_t\": {oc_t}, \"oh_t\": {oh_t}, \"ow_t\": {ow_t} }}"
                    );
                }
                ScheduleChoice::Matmul { m_t, n_t } => {
                    let _ = write!(s, "{{ \"op\": \"dense\", \"m_t\": {m_t}, \"n_t\": {n_t} }}");
                }
            }
            s.push_str(" }");
        }
        s.push_str("\n  ]\n}\n");
        s
    }

    /// Parse the JSON record format.
    pub fn from_json(text: &str) -> Result<Self> {
        let root = json::parse(text)?;
        let version = root.get("version").and_then(json::Value::as_u64).unwrap_or(0);
        if version != 1 {
            bail!("unsupported tuning-record version {version}");
        }
        let mut store = TuningRecords::new();
        let records = root
            .get("records")
            .and_then(json::Value::as_array)
            .context("missing \"records\" array")?;
        for (i, rec) in records.iter().enumerate() {
            let field = |name: &str| -> Result<u64> {
                rec.get(name)
                    .and_then(json::Value::as_u64)
                    .with_context(|| format!("record {i}: missing integer field {name:?}"))
            };
            let key = RecordKey {
                config_fp: field("config_fp")?,
                virtual_threads: field("vt")? as usize,
                sched_fp: field("sched_fp")?,
            };
            let cycles = field("cycles")?;
            let choice_obj = rec.get("choice").context("missing \"choice\"")?;
            let cfield = |name: &str| -> Result<usize> {
                choice_obj
                    .get(name)
                    .and_then(json::Value::as_u64)
                    .map(|v| v as usize)
                    .with_context(|| format!("record {i}: choice missing field {name:?}"))
            };
            let op = choice_obj
                .get("op")
                .and_then(json::Value::as_str)
                .with_context(|| format!("record {i}: choice missing \"op\""))?;
            let choice = match op {
                "conv2d" => ScheduleChoice::Conv2d {
                    oc_t: cfield("oc_t")?,
                    oh_t: cfield("oh_t")?,
                    ow_t: cfield("ow_t")?,
                },
                "dense" => ScheduleChoice::Matmul { m_t: cfield("m_t")?, n_t: cfield("n_t")? },
                other => bail!("record {i}: unknown choice op {other:?}"),
            };
            store.insert(key, TuningRecord { choice, cycles });
        }
        Ok(store)
    }

    /// Write the store to `path` as JSON.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing tuning records to {}", path.display()))
    }

    /// Load a store from a JSON file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tuning records from {}", path.display()))?;
        Self::from_json(&text).with_context(|| format!("parsing {}", path.display()))
    }
}

/// The minimal JSON subset the record store (and the bench baselines)
/// need: objects, arrays, strings (no escapes beyond `\"` and `\\`),
/// numbers, booleans, null. Pure-digit integers parse to [`Value::Num`]
/// losslessly (the record store keys are full-range `u64` fingerprints);
/// anything with a sign, decimal point, or exponent parses to
/// [`Value::Float`].
pub mod json {
    use anyhow::{bail, Result};

    /// A parsed JSON value.
    #[derive(Clone, Debug, PartialEq)]
    pub enum Value {
        Obj(Vec<(String, Value)>),
        Arr(Vec<Value>),
        Str(String),
        Num(u64),
        Float(f64),
        Bool(bool),
        Null,
    }

    impl Value {
        /// Object field by key.
        pub fn get(&self, key: &str) -> Option<&Value> {
            match self {
                Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
                _ => None,
            }
        }

        /// Unsigned-integer view (exact — floats do not coerce).
        pub fn as_u64(&self) -> Option<u64> {
            match self {
                Value::Num(n) => Some(*n),
                _ => None,
            }
        }

        /// Numeric view: floats as-is, integers widened (lossy above
        /// 2^53, like every JSON reader).
        pub fn as_f64(&self) -> Option<f64> {
            match self {
                Value::Num(n) => Some(*n as f64),
                Value::Float(x) => Some(*x),
                _ => None,
            }
        }

        /// String view.
        pub fn as_str(&self) -> Option<&str> {
            match self {
                Value::Str(s) => Some(s),
                _ => None,
            }
        }

        /// Array view.
        pub fn as_array(&self) -> Option<&[Value]> {
            match self {
                Value::Arr(v) => Some(v),
                _ => None,
            }
        }
    }

    /// Parse one JSON document.
    pub fn parse(text: &str) -> Result<Value> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing content at byte {pos}");
        }
        Ok(v)
    }

    fn skip_ws(b: &[u8], pos: &mut usize) {
        while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
            *pos += 1;
        }
    }

    fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<()> {
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == c {
            *pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", c as char, *pos)
        }
    }

    fn parse_value(b: &[u8], pos: &mut usize) -> Result<Value> {
        skip_ws(b, pos);
        let Some(&c) = b.get(*pos) else { bail!("unexpected end of input") };
        match c {
            b'{' => {
                *pos += 1;
                let mut fields = Vec::new();
                skip_ws(b, pos);
                if b.get(*pos) == Some(&b'}') {
                    *pos += 1;
                    return Ok(Value::Obj(fields));
                }
                loop {
                    skip_ws(b, pos);
                    let key = match parse_value(b, pos)? {
                        Value::Str(s) => s,
                        other => bail!("object key must be a string, got {other:?}"),
                    };
                    expect(b, pos, b':')?;
                    let val = parse_value(b, pos)?;
                    fields.push((key, val));
                    skip_ws(b, pos);
                    match b.get(*pos) {
                        Some(&b',') => *pos += 1,
                        Some(&b'}') => {
                            *pos += 1;
                            return Ok(Value::Obj(fields));
                        }
                        _ => bail!("expected ',' or '}}' at byte {}", *pos),
                    }
                }
            }
            b'[' => {
                *pos += 1;
                let mut items = Vec::new();
                skip_ws(b, pos);
                if b.get(*pos) == Some(&b']') {
                    *pos += 1;
                    return Ok(Value::Arr(items));
                }
                loop {
                    items.push(parse_value(b, pos)?);
                    skip_ws(b, pos);
                    match b.get(*pos) {
                        Some(&b',') => *pos += 1,
                        Some(&b']') => {
                            *pos += 1;
                            return Ok(Value::Arr(items));
                        }
                        _ => bail!("expected ',' or ']' at byte {}", *pos),
                    }
                }
            }
            b'"' => {
                *pos += 1;
                let mut s = String::new();
                loop {
                    let Some(&c) = b.get(*pos) else { bail!("unterminated string") };
                    *pos += 1;
                    match c {
                        b'"' => return Ok(Value::Str(s)),
                        b'\\' => {
                            let Some(&e) = b.get(*pos) else { bail!("unterminated escape") };
                            *pos += 1;
                            match e {
                                b'"' => s.push('"'),
                                b'\\' => s.push('\\'),
                                other => bail!("unsupported escape \\{}", other as char),
                            }
                        }
                        other => s.push(other as char),
                    }
                }
            }
            b'0'..=b'9' | b'-' => {
                let start = *pos;
                if b[*pos] == b'-' {
                    *pos += 1;
                }
                let mut float = b[start] == b'-';
                while *pos < b.len() {
                    match b[*pos] {
                        b'0'..=b'9' => {}
                        b'.' | b'e' | b'E' | b'+' => float = true,
                        b'-' if float => {} // exponent sign, e.g. 1e-3
                        _ => break,
                    }
                    *pos += 1;
                }
                let text = std::str::from_utf8(&b[start..*pos]).expect("number chars are ascii");
                if float {
                    let x: f64 = text.parse()?;
                    if !x.is_finite() {
                        bail!("non-finite number {text:?}");
                    }
                    Ok(Value::Float(x))
                } else {
                    Ok(Value::Num(text.parse()?))
                }
            }
            b't' if b[*pos..].starts_with(b"true") => {
                *pos += 4;
                Ok(Value::Bool(true))
            }
            b'f' if b[*pos..].starts_with(b"false") => {
                *pos += 5;
                Ok(Value::Bool(false))
            }
            b'n' if b[*pos..].starts_with(b"null") => {
                *pos += 4;
                Ok(Value::Null)
            }
            other => bail!("unexpected character {:?} at byte {}", other as char, *pos),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(c: u64, vt: usize, s: u64) -> RecordKey {
        RecordKey { config_fp: c, virtual_threads: vt, sched_fp: s }
    }

    #[test]
    fn json_roundtrip_preserves_every_record() {
        let mut store = TuningRecords::new();
        store.insert(
            key(0xDEAD_BEEF_0000_0001, 2, 42),
            TuningRecord {
                choice: ScheduleChoice::Conv2d { oc_t: 2, oh_t: 7, ow_t: 28 },
                cycles: 123_456,
            },
        );
        store.insert(
            key(u64::MAX, 1, u64::MAX - 1),
            TuningRecord { choice: ScheduleChoice::Matmul { m_t: 4, n_t: 16 }, cycles: 99 },
        );
        let text = store.to_json();
        let back = TuningRecords::from_json(&text).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(
            back.lookup(0xDEAD_BEEF_0000_0001, 2, 42),
            Some(ScheduleChoice::Conv2d { oc_t: 2, oh_t: 7, ow_t: 28 })
        );
        assert_eq!(
            back.lookup(u64::MAX, 1, u64::MAX - 1),
            Some(ScheduleChoice::Matmul { m_t: 4, n_t: 16 })
        );
        // Round-tripping again is byte-identical (sorted, stable).
        assert_eq!(back.to_json(), text);
    }

    #[test]
    fn json_parser_handles_floats_without_losing_u64_exactness() {
        use super::json::{parse, Value};
        let v = parse(
            r#"{"int": 18446744073709551615, "pi": 3.25, "neg": -1.5,
                "exp": 2e-3, "negint": -7, "arr": [1, 0.5]}"#,
        )
        .unwrap();
        // Full-range integers stay exact (u64::MAX is not representable
        // in f64) ...
        assert_eq!(v.get("int").unwrap().as_u64(), Some(u64::MAX));
        // ... and never silently coerce from floats.
        assert_eq!(v.get("pi").unwrap().as_u64(), None);
        assert_eq!(v.get("pi").unwrap().as_f64(), Some(3.25));
        assert_eq!(v.get("neg").unwrap().as_f64(), Some(-1.5));
        assert_eq!(v.get("exp").unwrap().as_f64(), Some(2e-3));
        // Signed integers parse through the float path (the record
        // store never writes them; bench baselines may).
        assert_eq!(v.get("negint").unwrap(), &Value::Float(-7.0));
        let arr = v.get("arr").unwrap().as_array().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1], Value::Float(0.5));
        // Malformed numbers are rejected, not truncated.
        assert!(parse("--5").is_err());
        assert!(parse("1.2.3").is_err());
    }

    #[test]
    fn insert_keeps_the_better_record() {
        let mut store = TuningRecords::new();
        let k = key(1, 2, 3);
        let slow = TuningRecord { choice: ScheduleChoice::Matmul { m_t: 1, n_t: 1 }, cycles: 100 };
        let fast = TuningRecord { choice: ScheduleChoice::Matmul { m_t: 2, n_t: 2 }, cycles: 50 };
        assert!(store.insert(k, slow));
        assert!(store.insert(k, fast), "faster record must replace");
        assert!(!store.insert(k, slow), "slower record must not replace");
        assert_eq!(store.lookup(1, 2, 3), Some(fast.choice));
    }

    #[test]
    fn missing_lookup_is_none_and_bad_json_is_rejected() {
        let store = TuningRecords::new();
        assert_eq!(store.lookup(1, 2, 3), None);
        assert!(TuningRecords::from_json("not json").is_err());
        assert!(TuningRecords::from_json("{\"version\": 2, \"records\": []}").is_err());
        // A record with an unknown choice op is rejected, not skipped.
        let bad = "{\"version\": 1, \"records\": [{\"config_fp\": 1, \"vt\": 2, \
                   \"sched_fp\": 3, \"cycles\": 4, \"choice\": {\"op\": \"pool\"}}]}";
        assert!(TuningRecords::from_json(bad).is_err());
    }

    #[test]
    fn save_and_load_roundtrip_through_disk() {
        let mut store = TuningRecords::new();
        store.insert(
            key(7, 2, 8),
            TuningRecord {
                choice: ScheduleChoice::Conv2d { oc_t: 1, oh_t: 2, ow_t: 3 },
                cycles: 10,
            },
        );
        let path = std::env::temp_dir().join("vta_dse_records_test.json");
        store.save(&path).unwrap();
        let back = TuningRecords::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back.lookup(7, 2, 8), Some(ScheduleChoice::Conv2d { oc_t: 1, oh_t: 2, ow_t: 3 }));
    }
}
