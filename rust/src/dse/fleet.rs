//! Fleet allocation: search **compositions** of frontier configs — a
//! multiset of hardware variants with replica counts — instead of one
//! config replicated N times.
//!
//! The hardware DSE ([`crate::dse::run_dse`]) answers "which single
//! variant serves this suite best?". Under mixed traffic that framing
//! leaves performance on the table: conv-heavy and eltwise-heavy
//! request classes want different silicon, and a real deployment can
//! split its FPGA budget across both. `run_fleet_dse` enumerates every
//! multiset of candidate configs whose **total** BRAM/DSP/LUT spend
//! fits a fleet-wide [`ResourceBudget`] and whose device count fits
//! `max_devices`, scores each with the cost-routed modeled makespan
//! ([`modeled_fleet_makespan`]) over a deterministic mixed trace, and
//! emits the winner as a deployable [`FleetSpec`].
//!
//! Every single-config composition is in the search space, so the best
//! fleet **matches or beats the best homogeneous pool by
//! construction** — the `fleet-smoke` CI gate
//! ([`FleetDseReport::improved`]) can only fail if scoring itself
//! regresses. The search is exhaustive and deterministic: strict `<`
//! comparisons keep the first composition found in enumeration order
//! on ties.

use crate::arch::VtaConfig;
use crate::compiler::{config_fingerprint, op_impl};
use crate::dse::space::{ResourceBudget, ResourceUsage};
use crate::exec::serve::fleet::{
    modeled_fleet_makespan, FleetMember, FleetSpec, RoutePolicy, Router,
};
use crate::graph::{Graph, Placement};
use anyhow::{bail, ensure, Context, Result};

/// Candidate configs entered into the composition enumeration are
/// capped here (best first, as the caller orders them): the multiset
/// count grows as C(n + d, d) and the frontier rarely holds more than
/// a handful of genuinely distinct variants anyway.
pub const MAX_FLEET_CANDIDATES: usize = 8;

/// Fleet-search options.
#[derive(Clone, Debug)]
pub struct FleetDseOptions {
    /// Total replicas across the fleet (≥ 1).
    pub max_devices: usize,
    /// **Fleet-wide** resource budget: the summed usage of every
    /// replica must fit. Defaults to `max_devices` Zynq-7020 boards.
    pub budget: ResourceBudget,
    /// Mixed-traffic composition: requests per workload class, aligned
    /// with the `class_graphs` passed to [`run_fleet_dse`]. The scored
    /// trace interleaves them proportionally ([`interleave_classes`]).
    pub requests_per_class: Vec<usize>,
    /// Virtual threads the candidates must lower every class graph
    /// under, ∈ {1, 2}.
    pub virtual_threads: usize,
}

impl FleetDseOptions {
    /// Defaults: one Zynq-7020 of budget per device, vt = 2.
    pub fn new(max_devices: usize, requests_per_class: Vec<usize>) -> Self {
        FleetDseOptions {
            max_devices,
            budget: total_budget(ResourceBudget::zynq7020(), max_devices),
            requests_per_class,
            virtual_threads: 2,
        }
    }
}

/// `boards` boards' worth of a per-board budget — the fleet-wide
/// resource pool a composition's summed usage is checked against.
pub fn total_budget(per_board: ResourceBudget, boards: usize) -> ResourceBudget {
    ResourceBudget {
        bram18: per_board.bram18 * boards,
        dsp: per_board.dsp * boards,
        lut: per_board.lut * boards,
    }
}

/// Deterministic proportional interleave of class indices: emits
/// `counts[c]` requests of each class `c`, highest-quotient-first
/// (D'Hondt), ties preferring the **later** class. The later-class
/// tie-break is deliberate: with two equal classes the trace opens
/// with class 1, so a round-robin router (which pins routes to trace
/// parity) misroutes it onto group 0 — keeping the routing ablation's
/// baseline honest instead of accidentally cost-model-aligned.
pub fn interleave_classes(counts: &[usize]) -> Vec<usize> {
    let total: usize = counts.iter().sum();
    let mut emitted = vec![0usize; counts.len()];
    let mut out = Vec::with_capacity(total);
    for _ in 0..total {
        let mut best: Option<usize> = None;
        for c in 0..counts.len() {
            if emitted[c] >= counts[c] {
                continue;
            }
            let better = match best {
                None => true,
                // counts[c]/(emitted[c]+1) vs the incumbent, compared
                // by cross-multiplication; >= keeps the later class on
                // ties.
                Some(b) => counts[c] * (emitted[b] + 1) >= counts[b] * (emitted[c] + 1),
            };
            if better {
                best = Some(c);
            }
        }
        let c = best.expect("fewer than `total` requests emitted");
        emitted[c] += 1;
        out.push(c);
    }
    out
}

/// One scored fleet composition.
#[derive(Clone, Debug)]
pub struct FleetComposition {
    /// The deployable artifact (`vta serve --fleet` consumes this).
    pub spec: FleetSpec,
    /// Summed resource usage across every replica.
    pub usage: ResourceUsage,
    /// Modeled makespan of the trace under cost-model routing — the
    /// search objective.
    pub cost_makespan: f64,
    /// The same trace under round-robin routing (the routing-win
    /// ablation's baseline).
    pub roundrobin_makespan: f64,
    /// True when the composition uses a single config (a homogeneous
    /// pool).
    pub homogeneous: bool,
}

/// The fleet-search outcome.
#[derive(Clone, Debug)]
pub struct FleetDseReport {
    /// Best composition overall (lowest cost-routed makespan; first
    /// found in enumeration order on ties).
    pub best: FleetComposition,
    /// Best **single-config** composition — the strongest homogeneous
    /// pool the same budget buys.
    pub best_homogeneous: FleetComposition,
    /// Distinct feasible candidate configs entered into enumeration.
    pub candidates: usize,
    /// Compositions scored (incl. over-budget ones).
    pub evaluated: usize,
    /// Compositions rejected for exceeding the fleet budget.
    pub infeasible: usize,
    /// The class trace every composition was scored on.
    pub trace: Vec<usize>,
}

impl FleetDseReport {
    /// True when the best fleet matches or beats the best homogeneous
    /// pool — the `fleet-smoke` CI gate. Holds by construction (every
    /// single-config composition is enumerated), so a failure means
    /// the scoring itself broke.
    pub fn improved(&self) -> bool {
        self.best.cost_makespan <= self.best_homogeneous.cost_makespan
    }
}

struct SearchState<'a> {
    configs: &'a [VtaConfig],
    usages: &'a [ResourceUsage],
    class_graphs: &'a [&'a Graph],
    trace: &'a [usize],
    budget: ResourceBudget,
    evaluated: usize,
    infeasible: usize,
    best: Option<FleetComposition>,
    best_homogeneous: Option<FleetComposition>,
}

impl SearchState<'_> {
    /// Assign `counts[idx..]` every split of `remaining` devices, in
    /// deterministic lexicographic order, scoring each completed
    /// assignment.
    fn visit(&mut self, counts: &mut [usize], idx: usize, remaining: usize) {
        if idx == counts.len() {
            self.score(counts);
            return;
        }
        for c in 0..=remaining {
            counts[idx] = c;
            self.visit(counts, idx + 1, remaining - c);
        }
        counts[idx] = 0;
    }

    fn score(&mut self, counts: &[usize]) {
        if counts.iter().all(|&c| c == 0) {
            return;
        }
        self.evaluated += 1;
        let mut usage = ResourceUsage { bram18: 0, dsp: 0, lut: 0 };
        for (u, &c) in self.usages.iter().zip(counts) {
            usage.bram18 += u.bram18 * c;
            usage.dsp += u.dsp * c;
            usage.lut += u.lut * c;
        }
        if usage.bram18 > self.budget.bram18
            || usage.dsp > self.budget.dsp
            || usage.lut > self.budget.lut
        {
            self.infeasible += 1;
            return;
        }
        let mut cfgs: Vec<VtaConfig> = Vec::new();
        let mut devices: Vec<usize> = Vec::new();
        for (cfg, &c) in self.configs.iter().zip(counts) {
            if c > 0 {
                cfgs.push(cfg.clone());
                devices.push(c);
            }
        }
        let cost_routes = Router::new(RoutePolicy::CostModel, &cfgs, self.class_graphs)
            .route_trace(self.trace);
        let rr_routes = Router::new(RoutePolicy::RoundRobin, &cfgs, self.class_graphs)
            .route_trace(self.trace);
        let cost =
            modeled_fleet_makespan(&cfgs, &devices, self.class_graphs, self.trace, &cost_routes);
        let rr = modeled_fleet_makespan(&cfgs, &devices, self.class_graphs, self.trace, &rr_routes);
        let comp = FleetComposition {
            spec: FleetSpec::new(
                cfgs.iter()
                    .zip(&devices)
                    .map(|(cfg, &d)| FleetMember { cfg: cfg.clone(), devices: d })
                    .collect(),
            ),
            usage,
            cost_makespan: cost,
            roundrobin_makespan: rr,
            homogeneous: cfgs.len() == 1,
        };
        if self.best.as_ref().map_or(true, |b| comp.cost_makespan < b.cost_makespan) {
            self.best = Some(comp.clone());
        }
        if comp.homogeneous
            && self
                .best_homogeneous
                .as_ref()
                .map_or(true, |b| comp.cost_makespan < b.cost_makespan)
        {
            self.best_homogeneous = Some(comp);
        }
    }
}

/// Search fleet compositions of `configs` serving `class_graphs` under
/// the mixed traffic in `opts`. `configs` should arrive best-first
/// (DSE frontier order) — only the first [`MAX_FLEET_CANDIDATES`]
/// distinct feasible candidates enter the enumeration.
///
/// A candidate is feasible when it validates and lowers **every**
/// VTA-placed node of every class graph at `opts.virtual_threads` —
/// the same offloadability contract the fleet runtimes enforce, so an
/// emitted [`FleetSpec`] is serveable by construction.
pub fn run_fleet_dse(
    configs: &[VtaConfig],
    class_graphs: &[&Graph],
    opts: &FleetDseOptions,
) -> Result<FleetDseReport> {
    ensure!(!configs.is_empty(), "fleet DSE needs at least one candidate config");
    ensure!(!class_graphs.is_empty(), "fleet DSE needs at least one workload class");
    ensure!(opts.max_devices >= 1, "a fleet has at least one device");
    ensure!(
        opts.virtual_threads == 1 || opts.virtual_threads == 2,
        "1 or 2 virtual threads"
    );
    ensure!(
        opts.requests_per_class.len() == class_graphs.len(),
        "one request count per workload class ({} counts, {} classes)",
        opts.requests_per_class.len(),
        class_graphs.len()
    );
    ensure!(
        opts.requests_per_class.iter().any(|&n| n > 0),
        "the scored trace needs at least one request"
    );

    // Feasible, distinct candidates, capped best-first.
    let mut candidates: Vec<VtaConfig> = Vec::new();
    let mut seen: Vec<u64> = Vec::new();
    for cfg in configs {
        if candidates.len() >= MAX_FLEET_CANDIDATES {
            break;
        }
        let fp = config_fingerprint(cfg);
        if seen.contains(&fp) || !cfg.validate().is_empty() {
            continue;
        }
        let offloads_all = class_graphs.iter().all(|g| {
            g.nodes
                .iter()
                .filter(|n| n.placement == Placement::Vta)
                .all(|n| op_impl(&n.op).offloadable(cfg, n, opts.virtual_threads))
        });
        if !offloads_all {
            continue;
        }
        seen.push(fp);
        candidates.push(cfg.clone());
    }
    if candidates.is_empty() {
        bail!("no candidate config lowers every workload class at vt={}", opts.virtual_threads);
    }

    let usages: Vec<ResourceUsage> = candidates.iter().map(ResourceUsage::of).collect();
    let trace = interleave_classes(&opts.requests_per_class);
    let mut st = SearchState {
        configs: &candidates,
        usages: &usages,
        class_graphs,
        trace: &trace,
        budget: opts.budget,
        evaluated: 0,
        infeasible: 0,
        best: None,
        best_homogeneous: None,
    };
    let mut counts = vec![0usize; candidates.len()];
    st.visit(&mut counts, 0, opts.max_devices);

    let best = st.best.context("no fleet composition fits the resource budget")?;
    // Any feasible composition contains a feasible single-config one
    // (drop all but one config: usage only shrinks), so `best` existing
    // implies a homogeneous best exists.
    let best_homogeneous =
        st.best_homogeneous.expect("a feasible fleet implies a feasible homogeneous pool");
    Ok(FleetDseReport {
        best,
        best_homogeneous,
        candidates: candidates.len(),
        evaluated: st.evaluated,
        infeasible: st.infeasible,
        trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{Conv2dParams, Requant};
    use crate::graph::{partition, Op, PartitionPolicy};
    use crate::util::{Tensor, XorShiftRng};

    fn conv_graph(cfg: &VtaConfig) -> Graph {
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
        let p = Conv2dParams {
            h: 8,
            w: 8,
            ic: 16,
            oc: 16,
            k: 3,
            s: 1,
            requant: Requant { shift: 6, relu: false },
        };
        let c = g.add("conv", Op::Conv2d { p }, &[x]).unwrap();
        let mut rng = XorShiftRng::new(11);
        g.set_weights(c, Tensor::from_vec(&[16, 16, 3, 3], rng.vec_i8(16 * 16 * 9, -4, 4)).unwrap());
        partition(&mut g, &PartitionPolicy::paper(cfg));
        g
    }

    fn alu_graph(cfg: &VtaConfig) -> Graph {
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
        let r = g.add("relu", Op::Relu, &[x]).unwrap();
        let a = g.add("add", Op::Add, &[r, x]).unwrap();
        let _ = g.add("shr", Op::ShrImm { shift: 1 }, &[a]).unwrap();
        partition(&mut g, &PartitionPolicy::offload_all(cfg));
        g
    }

    /// The two-variant pair from the router tests: a conv-focused
    /// lanes-8 pynq (cheaper in LUTs, slower on eltwise) and stock
    /// pynq.
    fn candidate_pair() -> Vec<VtaConfig> {
        let pynq = VtaConfig::pynq();
        let mut conv_tuned = pynq.clone();
        conv_tuned.alu_lanes = 8;
        vec![conv_tuned, pynq]
    }

    #[test]
    fn interleave_is_proportional_and_opens_with_the_later_class() {
        let t = interleave_classes(&[8, 8]);
        assert_eq!(t.len(), 16);
        assert_eq!(t[0], 1, "equal classes: the later class leads");
        // Perfectly alternating on equal counts.
        for (i, &c) in t.iter().enumerate() {
            assert_eq!(c, (i + 1) % 2);
        }
        let t = interleave_classes(&[2, 4]);
        assert_eq!(t.iter().filter(|&&c| c == 0).count(), 2);
        assert_eq!(t.iter().filter(|&&c| c == 1).count(), 4);
        assert_eq!(t, interleave_classes(&[2, 4]), "deterministic");
        assert!(interleave_classes(&[0, 0]).is_empty());
    }

    /// Under a LUT budget that rules out two stock-pynq replicas, the
    /// search finds the mixed lanes-8 + stock fleet and it strictly
    /// beats every homogeneous option — the heterogeneity win the
    /// whole subsystem exists for.
    #[test]
    fn budget_squeezed_search_prefers_the_mixed_fleet() {
        let cands = candidate_pair();
        let conv = conv_graph(&cands[0]);
        let alu = alu_graph(&cands[0]);
        let graphs: Vec<&Graph> = vec![&conv, &alu];
        let mut opts = FleetDseOptions::new(2, vec![8, 8]);
        // Two boards of BRAM/DSP, but a LUT pool that fits
        // lanes8+lanes8 and lanes8+stock while excluding stock+stock.
        opts.budget = ResourceBudget { bram18: 560, dsp: 440, lut: 38_000 };
        let report = run_fleet_dse(&cands, &graphs, &opts).unwrap();

        assert_eq!(report.candidates, 2);
        assert!(report.infeasible >= 1, "stock+stock must be over budget");
        assert!(report.improved());
        assert_eq!(report.best.spec.members.len(), 2, "the winner is the mixed fleet");
        assert_eq!(report.best.spec.total_devices(), 2);
        assert!(
            report.best.cost_makespan < report.best_homogeneous.cost_makespan,
            "mixed fleet must strictly beat the best homogeneous pool: {} vs {}",
            report.best.cost_makespan,
            report.best_homogeneous.cost_makespan
        );
        assert!(report.best.usage.lut <= opts.budget.lut);

        // Determinism: same inputs, same winner.
        let again = run_fleet_dse(&cands, &graphs, &opts).unwrap();
        assert_eq!(again.best.spec, report.best.spec);
        assert_eq!(again.best.cost_makespan, report.best.cost_makespan);
    }

    /// With a roomy budget the single-config compositions are all in
    /// the space, so the fleet can only match or beat them — and the
    /// report says which homogeneous pool it had to beat.
    #[test]
    fn fleet_matches_or_beats_the_best_homogeneous_pool() {
        let cands = candidate_pair();
        let conv = conv_graph(&cands[0]);
        let alu = alu_graph(&cands[0]);
        let graphs: Vec<&Graph> = vec![&conv, &alu];
        let opts = FleetDseOptions::new(2, vec![8, 8]);
        let report = run_fleet_dse(&cands, &graphs, &opts).unwrap();
        assert!(report.improved());
        assert!(report.best.cost_makespan <= report.best_homogeneous.cost_makespan);
        assert!(report.best_homogeneous.homogeneous);
        // C(2 cands + 2 devices, 2) - 1 empty = 5 non-empty multisets.
        assert_eq!(report.evaluated, 5);
        assert_eq!(report.infeasible, 0);
        // The scored trace follows the requested mix.
        assert_eq!(report.trace.len(), 16);
        assert_eq!(report.trace.iter().filter(|&&c| c == 0).count(), 8);
    }

    /// Candidates that cannot lower a class graph are filtered before
    /// enumeration, and an impossible budget is a hard error.
    #[test]
    fn infeasible_candidates_and_budgets_are_rejected() {
        let cands = candidate_pair();
        let conv = conv_graph(&cands[0]);
        let alu = alu_graph(&cands[0]);
        let graphs: Vec<&Graph> = vec![&conv, &alu];

        // Duplicate candidates collapse to one.
        let dup = vec![cands[1].clone(), cands[1].clone()];
        let report = run_fleet_dse(&dup, &graphs, &FleetDseOptions::new(2, vec![4, 4])).unwrap();
        assert_eq!(report.candidates, 1);
        assert!(report.best.homogeneous);

        // A budget no composition fits.
        let mut opts = FleetDseOptions::new(2, vec![4, 4]);
        opts.budget = ResourceBudget { bram18: 1, dsp: 1, lut: 1 };
        assert!(run_fleet_dse(&cands, &graphs, &opts).is_err());

        // A config too small to lower the conv is filtered; with no
        // survivors the search reports the offloadability failure.
        let mut tiny = VtaConfig::pynq();
        tiny.inp_buf_bytes = 0;
        let err = run_fleet_dse(&[tiny], &graphs, &FleetDseOptions::new(1, vec![1, 1]));
        assert!(err.is_err());
    }
}
