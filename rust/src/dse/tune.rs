//! Schedule tuning: per (hardware config, operator) search over the
//! tiling factors the planners otherwise fix greedily.
//!
//! The TVM lineage this repo follows ("learning-based frameworks pick
//! schedules by measured cost, not heuristics") is realized in the
//! simplest honest form: every candidate [`ScheduleChoice`] is
//! **measured** by running the fully lowered operator on the
//! cycle-accurate simulator — the same path serving traffic takes —
//! and the best measured schedule wins. Simulated timing is
//! data-independent, so a single synthetic run per candidate is an
//! exact cost model.

use crate::arch::VtaConfig;
use crate::compiler::{
    compile_eltwise, compile_upsample2x, lower_conv2d_tuned, lower_matmul_tuned, pack_acc_i32,
    pack_acc_nchw, pack_activations, pack_matrix_a, pack_matrix_w, pack_weights, plan_conv2d,
    plan_conv2d_tuned, plan_matmul, plan_matmul_tuned, CompileError, Conv2dParams, EltwiseKind,
    MatmulParams, ScheduleChoice,
};
use crate::runtime::VtaRuntime;
use crate::util::{Tensor, XorShiftRng};

/// Device-DRAM size used by tuning runs — large enough for every
/// Table 1 layer's images plus kernel arenas, small enough that the
/// per-candidate runtime setup stays cheap (tuning allocates a fresh
/// device per measurement).
const TUNE_DRAM: usize = 64 << 20;

/// Outcome of tuning one operator on one config.
#[derive(Clone, Copy, Debug)]
pub struct TuneOutcome {
    /// The winning schedule (`None` = the planner default won).
    pub choice: Option<ScheduleChoice>,
    /// Simulated cycles of the winner.
    pub cycles: u64,
    /// Simulated cycles of the planner default (the tuning baseline).
    pub default_cycles: u64,
    /// Candidate schedules actually measured (excludes infeasible
    /// draws).
    pub measured: usize,
}

/// Measure one conv2d lowering (default or tuned) in simulated cycles.
pub fn eval_conv2d(
    cfg: &VtaConfig,
    p: &Conv2dParams,
    virtual_threads: usize,
    choice: Option<&ScheduleChoice>,
    seed: u64,
) -> Result<u64, CompileError> {
    let mut rng = XorShiftRng::new(seed);
    let inp = Tensor::from_vec(&[1, p.ic, p.h, p.w], rng.vec_i8(p.ic * p.h * p.w, -8, 8))
        .expect("synth input");
    let wgt = Tensor::from_vec(&[p.oc, p.ic, p.k, p.k], rng.vec_i8(p.oc * p.ic * p.k * p.k, -4, 4))
        .expect("synth weights");
    let mut rt = VtaRuntime::new(cfg, TUNE_DRAM);
    let out = lower_conv2d_tuned(
        &mut rt,
        p,
        &pack_activations(cfg, &inp),
        &pack_weights(cfg, &wgt),
        virtual_threads,
        choice,
    )?;
    Ok(out.stats.total_cycles)
}

/// Measure one matmul lowering (default or tuned) in simulated cycles.
pub fn eval_matmul(
    cfg: &VtaConfig,
    p: &MatmulParams,
    virtual_threads: usize,
    choice: Option<&ScheduleChoice>,
    seed: u64,
) -> Result<u64, CompileError> {
    let mut rng = XorShiftRng::new(seed);
    let a = Tensor::from_vec(&[p.m, p.k], rng.vec_i8(p.m * p.k, -8, 8)).expect("synth A");
    let w = Tensor::from_vec(&[p.n, p.k], rng.vec_i8(p.n * p.k, -4, 4)).expect("synth W");
    let mut rt = VtaRuntime::new(cfg, TUNE_DRAM);
    let out = lower_matmul_tuned(
        &mut rt,
        p,
        &pack_matrix_a(cfg, &a),
        &pack_matrix_w(cfg, &w),
        virtual_threads,
        choice,
    )?;
    Ok(out.stats.total_cycles)
}

/// Measure one elementwise ALU operator (no tunable schedule: the
/// strip size is already maximal, but the *hardware* axes — ALU lanes,
/// register-file depth — still move its cycle count across configs).
pub fn eval_eltwise(
    cfg: &VtaConfig,
    kind: EltwiseKind,
    len: usize,
    virtual_threads: usize,
    seed: u64,
) -> Result<u64, CompileError> {
    let mut rng = XorShiftRng::new(seed);
    let mut rt = VtaRuntime::new(cfg, TUNE_DRAM);
    let compiled = compile_eltwise(&mut rt, kind, len, virtual_threads)?;
    let shape = [len];
    let packed: Vec<Vec<i8>> = (0..kind.operands())
        .map(|_| {
            let t = Tensor::from_vec(&shape, rng.vec_i8(len, -100, 100)).expect("synth operand");
            pack_acc_i32(cfg, &t)
        })
        .collect();
    let (_, stats) = compiled.execute(&mut rt, &packed)?;
    compiled.free(&mut rt)?;
    Ok(stats.total_cycles)
}

/// Measure one nearest-neighbor 2x upsampling pass (no tunable
/// schedule — whole rows strip-mine at the maximal chunk; the hardware
/// axes still move its store-bound cycle count across configs).
pub fn eval_upsample2x(
    cfg: &VtaConfig,
    c: usize,
    h: usize,
    w: usize,
    virtual_threads: usize,
    seed: u64,
) -> Result<u64, CompileError> {
    let mut rng = XorShiftRng::new(seed);
    let mut rt = VtaRuntime::new(cfg, TUNE_DRAM);
    let compiled = compile_upsample2x(&mut rt, 1, c, h, w, virtual_threads)?;
    let t = Tensor::from_vec(&[1, c, h, w], rng.vec_i8(c * h * w, -100, 100))
        .expect("synth input");
    let (_, stats) = compiled.execute(&mut rt, &[pack_acc_nchw(cfg, &t)])?;
    compiled.free(&mut rt)?;
    Ok(stats.total_cycles)
}

/// Power-of-two menu covering `[1, max]`, always including `max`.
fn pow2_menu(max: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut x = 1usize;
    while x < max {
        v.push(x);
        x *= 2;
    }
    v.push(max);
    v
}

/// Tune conv2d tiling on `cfg`: measure the planner default plus up to
/// `trials` random candidate tilings, keep the fastest.
pub fn tune_conv2d(
    cfg: &VtaConfig,
    p: &Conv2dParams,
    virtual_threads: usize,
    trials: usize,
    rng: &mut XorShiftRng,
) -> Result<TuneOutcome, CompileError> {
    // Feasibility gate + candidate bounds from the default plan.
    let plan0 = plan_conv2d(cfg, p, virtual_threads)?;
    let default_cycles = eval_conv2d(cfg, p, virtual_threads, None, 17)?;
    let mut best_choice: Option<ScheduleChoice> = None;
    let mut best_cycles = default_cycles;

    let oc_menu = pow2_menu(plan0.ocb);
    let oh_menu = pow2_menu(plan0.oh);
    let ow_menu = pow2_menu(plan0.ow);
    let mut measured = 0usize;
    let mut attempts = 0usize;
    while measured < trials && attempts < trials * 8 {
        attempts += 1;
        let choice = ScheduleChoice::Conv2d {
            oc_t: oc_menu[rng.next_below(oc_menu.len() as u64) as usize],
            oh_t: oh_menu[rng.next_below(oh_menu.len() as u64) as usize],
            ow_t: ow_menu[rng.next_below(ow_menu.len() as u64) as usize],
        };
        // Skip choices that reproduce the default tiling or don't plan.
        let Ok(plan) = plan_conv2d_tuned(cfg, p, virtual_threads, Some(&choice)) else {
            continue;
        };
        if (plan.oc_t, plan.oh_t, plan.ow_t) == (plan0.oc_t, plan0.oh_t, plan0.ow_t) {
            continue;
        }
        measured += 1;
        let cycles = eval_conv2d(cfg, p, virtual_threads, Some(&choice), 17)?;
        if cycles < best_cycles {
            best_cycles = cycles;
            best_choice = Some(choice);
        }
    }
    Ok(TuneOutcome { choice: best_choice, cycles: best_cycles, default_cycles, measured })
}

/// Tune matmul tiling on `cfg`: planner default plus up to `trials`
/// random (m_t, n_t) candidates.
pub fn tune_matmul(
    cfg: &VtaConfig,
    p: &MatmulParams,
    virtual_threads: usize,
    trials: usize,
    rng: &mut XorShiftRng,
) -> Result<TuneOutcome, CompileError> {
    let plan0 = plan_matmul(cfg, p, virtual_threads)?;
    let default_cycles = eval_matmul(cfg, p, virtual_threads, None, 19)?;
    let mut best_choice: Option<ScheduleChoice> = None;
    let mut best_cycles = default_cycles;

    let m_rows = p.m / cfg.gemm.batch;
    let m_menu = pow2_menu(m_rows);
    let n_menu = pow2_menu(plan0.nb);
    let mut measured = 0usize;
    let mut attempts = 0usize;
    while measured < trials && attempts < trials * 8 {
        attempts += 1;
        let choice = ScheduleChoice::Matmul {
            m_t: m_menu[rng.next_below(m_menu.len() as u64) as usize],
            n_t: n_menu[rng.next_below(n_menu.len() as u64) as usize],
        };
        let Ok(plan) = plan_matmul_tuned(cfg, p, virtual_threads, Some(&choice)) else {
            continue;
        };
        if (plan.m_t, plan.n_t) == (plan0.m_t, plan0.n_t) {
            continue;
        }
        measured += 1;
        let cycles = eval_matmul(cfg, p, virtual_threads, Some(&choice), 19)?;
        if cycles < best_cycles {
            best_cycles = cycles;
            best_choice = Some(choice);
        }
    }
    Ok(TuneOutcome { choice: best_choice, cycles: best_cycles, default_cycles, measured })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::Requant;

    fn small_conv() -> Conv2dParams {
        let requant = Requant { shift: 6, relu: false };
        Conv2dParams { h: 8, w: 8, ic: 32, oc: 32, k: 3, s: 1, requant }
    }

    /// Tuning never regresses: the winner is at worst the planner
    /// default, and any returned choice re-plans successfully.
    #[test]
    fn tuned_conv_never_loses_to_the_default() {
        let cfg = VtaConfig::pynq();
        let p = small_conv();
        let mut rng = XorShiftRng::new(0x77);
        let out = tune_conv2d(&cfg, &p, 2, 6, &mut rng).unwrap();
        assert!(out.cycles <= out.default_cycles);
        if let Some(choice) = out.choice {
            assert!(plan_conv2d_tuned(&cfg, &p, 2, Some(&choice)).is_ok());
            assert!(out.cycles < out.default_cycles, "a choice is only kept when it wins");
        }
    }

    /// A tuned schedule produces bit-identical results to the default
    /// lowering — tuning changes timing, never semantics.
    #[test]
    fn tuned_conv_is_semantically_transparent() {
        let cfg = VtaConfig::pynq();
        let p = small_conv();
        let mut rng = XorShiftRng::new(5);
        let inp = Tensor::from_vec(&[1, p.ic, p.h, p.w], rng.vec_i8(p.ic * p.h * p.w, -5, 5))
            .unwrap();
        let wgt =
            Tensor::from_vec(&[p.oc, p.ic, p.k, p.k], rng.vec_i8(p.oc * p.ic * p.k * p.k, -4, 4))
                .unwrap();
        let ip = pack_activations(&cfg, &inp);
        let wp = pack_weights(&cfg, &wgt);

        let mut rt1 = VtaRuntime::new(&cfg, 64 << 20);
        let default = lower_conv2d_tuned(&mut rt1, &p, &ip, &wp, 2, None).unwrap();
        for choice in [
            ScheduleChoice::Conv2d { oc_t: 1, oh_t: 2, ow_t: 8 },
            ScheduleChoice::Conv2d { oc_t: 2, oh_t: 8, ow_t: 4 },
        ] {
            let mut rt2 = VtaRuntime::new(&cfg, 64 << 20);
            let tuned = lower_conv2d_tuned(&mut rt2, &p, &ip, &wp, 2, Some(&choice)).unwrap();
            assert_eq!(tuned.out, default.out, "tuned schedule changed results ({choice:?})");
            assert_eq!(tuned.stats.gemm_uops, default.stats.gemm_uops);
        }
    }

    /// Same transparency for the dense path.
    #[test]
    fn tuned_matmul_is_semantically_transparent() {
        let cfg = VtaConfig::pynq();
        let p = MatmulParams { m: 4, k: 64, n: 64, requant: Requant { shift: 6, relu: false } };
        let mut rng = XorShiftRng::new(6);
        let a = Tensor::from_vec(&[p.m, p.k], rng.vec_i8(p.m * p.k, -5, 5)).unwrap();
        let w = Tensor::from_vec(&[p.n, p.k], rng.vec_i8(p.n * p.k, -4, 4)).unwrap();
        let ap = pack_matrix_a(&cfg, &a);
        let wp = pack_matrix_w(&cfg, &w);

        let mut rt1 = VtaRuntime::new(&cfg, 32 << 20);
        let default = lower_matmul_tuned(&mut rt1, &p, &ap, &wp, 2, None).unwrap();
        let choice = ScheduleChoice::Matmul { m_t: 1, n_t: 2 };
        let mut rt2 = VtaRuntime::new(&cfg, 32 << 20);
        let tuned = lower_matmul_tuned(&mut rt2, &p, &ap, &wp, 2, Some(&choice)).unwrap();
        assert_eq!(tuned.out, default.out, "tuned schedule changed results");
    }

    /// Infeasible explicit schedules are rejected by planning, and a
    /// schedule of the wrong kind is an error, not a silent fallback.
    #[test]
    fn infeasible_and_mismatched_schedules_are_rejected() {
        let cfg = VtaConfig::pynq();
        let p = small_conv();
        // An absurd strip: the whole output as one strip with every
        // channel resident overflows the accumulator budget.
        let big = ScheduleChoice::Conv2d { oc_t: 1 << 10, oh_t: 1 << 10, ow_t: 1 << 10 };
        // Clamped to the layer extent it may fit small layers, so use
        // one that cannot: oc_t clamps to ocb=2, oh_t/ow_t to 8 → may
        // fit. Instead shrink the budget.
        let mut tiny = cfg.clone();
        tiny.acc_buf_bytes = 4 * tiny.acc_tile_bytes();
        assert!(plan_conv2d_tuned(&tiny, &p, 2, Some(&big)).is_err());
        let wrong = ScheduleChoice::Matmul { m_t: 1, n_t: 1 };
        assert!(matches!(
            plan_conv2d_tuned(&cfg, &p, 2, Some(&wrong)),
            Err(crate::compiler::PlanError::WrongSchedule { .. })
        ));
    }
}
