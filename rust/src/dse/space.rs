//! The hardware candidate space: enumerable [`VtaConfig`] axes under
//! an FPGA resource model.
//!
//! The paper's flow "performs design space exploration to generate a
//! customized hardware architecture" — candidates are only meaningful
//! if they would actually place and route on the target part, so every
//! sampled variant is filtered through [`ResourceBudget::fits`]
//! (BRAM / DSP / LUT cost functions over the config) on top of
//! [`VtaConfig::validate`].

use crate::arch::{GemmShape, VtaConfig};
use crate::util::XorShiftRng;

/// Estimated FPGA resource usage of a VTA variant — the cost side of
/// the resource model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResourceUsage {
    /// 18 kbit block RAMs backing the five scratchpads.
    pub bram18: usize,
    /// DSP48 slices backing the GEMM multipliers.
    pub dsp: usize,
    /// Logic LUTs: control + GEMM adder trees + the tensor ALU lanes.
    pub lut: usize,
}

impl ResourceUsage {
    /// Cost functions over a config. The models are deliberately
    /// simple, monotone approximations:
    /// * BRAM: total SRAM bytes across the five buffers, packed into
    ///   18 kbit blocks.
    /// * DSP: one multiplier per MAC lane; two int8 multiplies pack
    ///   into one DSP48 slice (the standard 8-bit packing trick), and
    ///   wider operands take a full slice each.
    /// * LUT: a fixed control overhead, plus the GEMM adder tree and
    ///   the vector ALU lanes.
    pub fn of(cfg: &VtaConfig) -> Self {
        let sram_bytes = cfg.inp_buf_bytes
            + cfg.wgt_buf_bytes
            + cfg.acc_buf_bytes
            + cfg.out_buf_bytes
            + cfg.uop_buf_bytes;
        let bram18 = (sram_bytes * 8).div_ceil(18 * 1024);
        let macs = cfg.gemm.macs_per_cycle();
        let dsp = if cfg.inp_bits <= 8 && cfg.wgt_bits <= 8 { macs.div_ceil(2) } else { macs };
        let lut = 8_000 + 30 * macs + 250 * cfg.alu_lanes;
        ResourceUsage { bram18, dsp, lut }
    }
}

/// An FPGA resource budget the hardware search must stay inside.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResourceBudget {
    pub bram18: usize,
    pub dsp: usize,
    pub lut: usize,
}

impl ResourceBudget {
    /// The paper's evaluation part: the Pynq board's Zynq-7020
    /// (140 BRAM36 = 280 BRAM18, 220 DSP48, 53 200 LUTs).
    pub fn zynq7020() -> Self {
        ResourceBudget { bram18: 280, dsp: 220, lut: 53_200 }
    }

    /// True when `cfg`'s estimated usage fits this budget.
    pub fn fits(&self, cfg: &VtaConfig) -> bool {
        let u = ResourceUsage::of(cfg);
        u.bram18 <= self.bram18 && u.dsp <= self.dsp && u.lut <= self.lut
    }
}

/// Menu of values per tunable axis. Kept as constants so sampling and
/// mutation draw from the same sets.
const BLOCK_DIMS: [usize; 3] = [8, 16, 32];
const INP_KIB: [usize; 4] = [16, 32, 64, 128];
const WGT_KIB: [usize; 4] = [64, 128, 256, 512];
const ACC_KIB: [usize; 4] = [32, 64, 128, 256];
const OUT_KIB: [usize; 3] = [16, 32, 64];
const UOP_KIB: [usize; 4] = [8, 16, 32, 64];
const ALU_LANES: [usize; 4] = [8, 16, 32, 64];

fn pick<const N: usize>(menu: &[usize; N], rng: &mut XorShiftRng) -> usize {
    menu[rng.next_below(N as u64) as usize]
}

/// The enumerable hardware design space (GEMM geometry, SRAM depths,
/// ALU width) under a resource budget. Clock and DRAM model are held
/// at the Pynq point so candidate scores stay cycle-comparable.
#[derive(Clone, Copy, Debug)]
pub struct ConfigSpace {
    pub budget: ResourceBudget,
}

impl ConfigSpace {
    /// Space over the default Zynq-7020 budget.
    pub fn new() -> Self {
        ConfigSpace { budget: ResourceBudget::zynq7020() }
    }

    /// Draw one random candidate: rejection-sample until the variant
    /// both validates and fits the budget (the menus are small enough
    /// that this terminates in a handful of draws).
    pub fn sample(&self, rng: &mut XorShiftRng) -> VtaConfig {
        loop {
            let mut cfg = VtaConfig::pynq();
            cfg.gemm = GemmShape {
                batch: 1,
                block_in: pick(&BLOCK_DIMS, rng),
                block_out: pick(&BLOCK_DIMS, rng),
            };
            cfg.inp_buf_bytes = pick(&INP_KIB, rng) * 1024;
            cfg.wgt_buf_bytes = pick(&WGT_KIB, rng) * 1024;
            cfg.acc_buf_bytes = pick(&ACC_KIB, rng) * 1024;
            cfg.out_buf_bytes = pick(&OUT_KIB, rng) * 1024;
            cfg.uop_buf_bytes = pick(&UOP_KIB, rng) * 1024;
            cfg.alu_lanes = pick(&ALU_LANES, rng).min(cfg.gemm.batch * cfg.gemm.block_out);
            if self.accepts(&cfg) {
                return cfg;
            }
        }
    }

    /// Mutate one axis of `base` to a neighboring menu value — the
    /// greedy-refine move. Falls back to a fresh sample if no valid
    /// single-axis mutation is found after a few tries.
    pub fn mutate(&self, base: &VtaConfig, rng: &mut XorShiftRng) -> VtaConfig {
        for _ in 0..16 {
            let mut cfg = base.clone();
            match rng.next_below(8) {
                0 => cfg.gemm.block_in = pick(&BLOCK_DIMS, rng),
                1 => cfg.gemm.block_out = pick(&BLOCK_DIMS, rng),
                2 => cfg.inp_buf_bytes = pick(&INP_KIB, rng) * 1024,
                3 => cfg.wgt_buf_bytes = pick(&WGT_KIB, rng) * 1024,
                4 => cfg.acc_buf_bytes = pick(&ACC_KIB, rng) * 1024,
                5 => cfg.uop_buf_bytes = pick(&UOP_KIB, rng) * 1024,
                6 => cfg.out_buf_bytes = pick(&OUT_KIB, rng) * 1024,
                _ => cfg.alu_lanes = pick(&ALU_LANES, rng),
            }
            cfg.alu_lanes = cfg.alu_lanes.min(cfg.gemm.batch * cfg.gemm.block_out);
            if cfg != *base && self.accepts(&cfg) {
                return cfg;
            }
        }
        self.sample(rng)
    }

    /// Validity + budget filter.
    pub fn accepts(&self, cfg: &VtaConfig) -> bool {
        cfg.validate().is_empty() && self.budget.fits(cfg)
    }
}

impl Default for ConfigSpace {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pynq_fits_the_zynq7020_budget() {
        let budget = ResourceBudget::zynq7020();
        let pynq = VtaConfig::pynq();
        assert!(budget.fits(&pynq), "usage {:?}", ResourceUsage::of(&pynq));
    }

    #[test]
    fn oversized_variants_are_rejected() {
        let budget = ResourceBudget::zynq7020();
        // A 32x32 GEMM core needs 512 packed-int8 DSPs — over the 220
        // on the part.
        let mut big = VtaConfig::pynq();
        big.gemm = GemmShape { batch: 1, block_in: 32, block_out: 32 };
        assert!(!budget.fits(&big));
        // Doubling every SRAM blows the BRAM budget.
        let mut deep = VtaConfig::pynq();
        deep.inp_buf_bytes *= 4;
        deep.wgt_buf_bytes *= 4;
        deep.acc_buf_bytes *= 4;
        assert!(!budget.fits(&deep));
    }

    #[test]
    fn sampled_candidates_are_valid_and_in_budget() {
        let space = ConfigSpace::new();
        let mut rng = XorShiftRng::new(0xD5E);
        for _ in 0..50 {
            let cfg = space.sample(&mut rng);
            assert!(cfg.validate().is_empty(), "invalid sample: {cfg:?}");
            assert!(space.budget.fits(&cfg), "over budget: {cfg:?}");
            assert!(cfg.alu_lanes <= cfg.gemm.batch * cfg.gemm.block_out);
        }
    }

    #[test]
    fn mutation_stays_in_budget_and_moves() {
        let space = ConfigSpace::new();
        let mut rng = XorShiftRng::new(0xD5E2);
        let base = VtaConfig::pynq();
        for _ in 0..20 {
            let m = space.mutate(&base, &mut rng);
            assert!(space.accepts(&m));
        }
    }
}
