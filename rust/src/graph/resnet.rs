//! ResNet-18 workload builder (§5, Table 1).
//!
//! Builds the full inference graph with deterministic synthetic int8
//! weights (the evaluation measures performance, not accuracy — see
//! DESIGN.md §2). The twelve conv configurations C1–C12 of Table 1 all
//! appear; the builder also exposes them individually for the
//! single-kernel benchmarks.

use super::ir::{Graph, GraphError, Op};
use crate::compiler::{Conv2dParams, MatmulParams, Requant};
use crate::util::{Tensor, XorShiftRng};

/// Requantization used by every layer (shift tuned so synthetic int8
/// activations neither saturate nor vanish; the JAX model mirrors it).
pub const LAYER_SHIFT: u8 = 6;

/// Table 1 of the paper: the conv2d operators of ResNet-18.
/// `(name, H/W, IC, OC, K, S)`; all SAME padding.
pub const TABLE1: [(&str, usize, usize, usize, usize, usize); 12] = [
    ("C1", 224, 3, 64, 7, 2),
    ("C2", 56, 64, 64, 3, 1),
    ("C3", 56, 64, 64, 1, 1),
    ("C4", 56, 64, 128, 3, 2),
    ("C5", 56, 64, 128, 1, 2),
    ("C6", 28, 128, 128, 3, 1),
    ("C7", 28, 128, 256, 3, 2),
    ("C8", 28, 128, 256, 1, 2),
    ("C9", 14, 256, 256, 3, 1),
    ("C10", 14, 256, 512, 3, 2),
    ("C11", 14, 256, 512, 1, 2),
    ("C12", 7, 512, 512, 3, 1),
];

/// Conv2dParams for a Table 1 row.
pub fn table1_params(row: usize) -> Conv2dParams {
    let (_, h, ic, oc, k, s) = TABLE1[row];
    Conv2dParams { h, w: h, ic, oc, k, s, requant: Requant { shift: LAYER_SHIFT, relu: false } }
}

/// Synthetic int8 weights for a conv layer, deterministic in `seed`.
/// Small range keeps post-shift activations in a healthy int8 band.
pub fn synth_conv_weights(seed: u64, oc: usize, ic: usize, k: usize) -> Tensor<i8> {
    let mut rng = XorShiftRng::new(seed);
    Tensor::from_vec(&[oc, ic, k, k], rng.vec_i8(oc * ic * k * k, -4, 4)).unwrap()
}

/// Synthetic int8 input image batch.
pub fn synth_input(seed: u64, n: usize, c: usize, h: usize, w: usize) -> Tensor<i8> {
    let mut rng = XorShiftRng::new(seed);
    Tensor::from_vec(&[n, c, h, w], rng.vec_i8(n * c * h * w, -16, 16)).unwrap()
}

/// Build the full ResNet-18 inference graph for batch size `n`.
///
/// Structure: conv1(7x7/2) → maxpool(3x3/2) → 4 stages x 2 basic
/// blocks → global-avg-pool → fc(512→1000). Downsample shortcuts are
/// 1x1 stride-2 convs (C5/C8/C11 in Table 1).
pub fn resnet18(n: usize, seed: u64) -> Result<Graph, GraphError> {
    let mut g = Graph::new();
    let rq = |relu: bool| Requant { shift: LAYER_SHIFT, relu };
    let mut wseed = seed;
    let mut next_seed = move || {
        wseed = wseed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        wseed
    };

    let input = g.add("input", Op::Input { shape: vec![n, 3, 224, 224] }, &[])?;

    // conv1 + relu (fused) + maxpool
    let c1p = Conv2dParams { h: 224, w: 224, ic: 3, oc: 64, k: 7, s: 2, requant: rq(true) };
    let conv1 = g.add("conv1", Op::Conv2d { p: c1p }, &[input])?;
    g.set_weights(conv1, synth_conv_weights(next_seed(), 64, 3, 7));
    let pool1 = g.add("maxpool", Op::MaxPool { k: 3, s: 2, pad: 1 }, &[conv1])?;

    // Four stages of two basic blocks each.
    let mut x = pool1;
    let mut in_ch = 64usize;
    let mut hw = 56usize;
    for (stage, &out_ch) in [64usize, 128, 256, 512].iter().enumerate() {
        for block in 0..2 {
            let stride = if stage > 0 && block == 0 { 2 } else { 1 };
            let name = |part: &str| format!("layer{}.{}.{}", stage + 1, block, part);

            // Main path: conv3x3(s) + relu, conv3x3(1).
            let pa = Conv2dParams {
                h: hw,
                w: hw,
                ic: in_ch,
                oc: out_ch,
                k: 3,
                s: stride,
                requant: rq(true),
            };
            let a = g.add(name("conv1"), Op::Conv2d { p: pa }, &[x])?;
            g.set_weights(a, synth_conv_weights(next_seed(), out_ch, in_ch, 3));
            let hw2 = pa.out_h();
            let pb = Conv2dParams {
                h: hw2,
                w: hw2,
                ic: out_ch,
                oc: out_ch,
                k: 3,
                s: 1,
                requant: rq(false),
            };
            let b = g.add(name("conv2"), Op::Conv2d { p: pb }, &[a])?;
            g.set_weights(b, synth_conv_weights(next_seed(), out_ch, out_ch, 3));

            // Shortcut: the first block of every stage uses a 1x1
            // projection conv, matching the paper's MXNet model —
            // Table 1's C3 is stage 1's dimension-preserving
            // projection (torchvision-style identity shortcuts would
            // have no 56x56 1x1 conv).
            let shortcut = if block == 0 {
                let pd = Conv2dParams {
                    h: hw,
                    w: hw,
                    ic: in_ch,
                    oc: out_ch,
                    k: 1,
                    s: stride,
                    requant: rq(false),
                };
                let d = g.add(name("downsample"), Op::Conv2d { p: pd }, &[x])?;
                g.set_weights(d, synth_conv_weights(next_seed(), out_ch, in_ch, 1));
                d
            } else {
                x
            };

            let sum = g.add(name("add"), Op::Add, &[b, shortcut])?;
            x = g.add(name("relu"), Op::Relu, &[sum])?;
            in_ch = out_ch;
            hw = hw2;
        }
    }

    // Head.
    let gap = g.add("avgpool", Op::GlobalAvgPool, &[x])?;
    let fcp = MatmulParams { m: n, k: 512, n: 1000, requant: rq(false) };
    let fc = g.add("fc", Op::Dense { p: fcp }, &[gap])?;
    let mut rng = XorShiftRng::new(next_seed());
    g.set_weights(fc, Tensor::from_vec(&[1000, 512], rng.vec_i8(512_000, -4, 4)).unwrap());

    g.validate()?;
    Ok(g)
}

/// A miniature ResNet for serving tests and mixed-traffic drivers:
/// conv stem (3 → `base_c` channels), two residual basic blocks,
/// global average pooling, dense classifier with 10 classes — the
/// ResNet-18 topology at bench scale, deterministic in `seed`.
/// `size` is the (square) input resolution for batch size `n`.
///
/// This is the conv-heavy workload class of the fleet-serving mixed
/// traffic (`vta serve --fleet --model mixed` pairs it with
/// [`style_net`](super::style::style_net)).
pub fn resnet_mini(n: usize, size: usize, seed: u64) -> Result<Graph, GraphError> {
    let base_c = 16usize;
    let rq = |relu: bool| Requant { shift: LAYER_SHIFT, relu };
    let mut g = Graph::new();
    let input = g.add("input", Op::Input { shape: vec![n, 3, size, size] }, &[])?;

    let stem_p =
        Conv2dParams { h: size, w: size, ic: 3, oc: base_c, k: 3, s: 1, requant: rq(true) };
    let stem = g.add("stem", Op::Conv2d { p: stem_p }, &[input])?;
    g.set_weights(stem, synth_conv_weights(seed, base_c, 3, 3));

    let mut x = stem;
    for b in 0u64..2 {
        let p1 = Conv2dParams {
            h: size,
            w: size,
            ic: base_c,
            oc: base_c,
            k: 3,
            s: 1,
            requant: rq(true),
        };
        let c1 = g.add(format!("block{b}.conv1"), Op::Conv2d { p: p1 }, &[x])?;
        g.set_weights(c1, synth_conv_weights(seed + 10 + b * 2, base_c, base_c, 3));
        let p2 = Conv2dParams {
            h: size,
            w: size,
            ic: base_c,
            oc: base_c,
            k: 3,
            s: 1,
            requant: rq(false),
        };
        let c2 = g.add(format!("block{b}.conv2"), Op::Conv2d { p: p2 }, &[c1])?;
        g.set_weights(c2, synth_conv_weights(seed + 11 + b * 2, base_c, base_c, 3));
        let sum = g.add(format!("block{b}.add"), Op::Add, &[c2, x])?;
        x = g.add(format!("block{b}.relu"), Op::Relu, &[sum])?;
    }

    let gap = g.add("avgpool", Op::GlobalAvgPool, &[x])?;
    let fcp = MatmulParams { m: n, k: base_c, n: 10, requant: Requant { shift: 2, relu: false } };
    let fc = g.add("fc", Op::Dense { p: fcp }, &[gap])?;
    let mut rng = XorShiftRng::new(seed ^ 0x5EED);
    g.set_weights(fc, Tensor::from_vec(&[10, base_c], rng.vec_i8(10 * base_c, -4, 4)).unwrap());

    g.validate()?;
    Ok(g)
}

/// Map each conv node of a built graph to its Table 1 label (by shape
/// match). Nodes that share a configuration share the label, as in the
/// paper ("configurations of all conv2d operators" — duplicates
/// collapse).
pub fn table1_label(p: &Conv2dParams) -> Option<&'static str> {
    TABLE1
        .iter()
        .find(|(_, h, ic, oc, k, s)| p.h == *h && p.ic == *ic && p.oc == *oc && p.k == *k && p.s == *s)
        .map(|(name, ..)| *name)
}

/// The distinct conv workloads of the graph, labeled and deduplicated,
/// with multiplicity (how many times each config runs in one forward
/// pass).
pub fn conv_workloads(g: &Graph) -> Vec<(&'static str, Conv2dParams, usize)> {
    let mut out: Vec<(&'static str, Conv2dParams, usize)> = Vec::new();
    for node in &g.nodes {
        if let Op::Conv2d { p } = &node.op {
            if let Some(label) = table1_label(p) {
                if let Some(entry) = out.iter_mut().find(|(l, ..)| *l == label) {
                    entry.2 += 1;
                } else {
                    out.push((label, *p, 1));
                }
            }
        }
    }
    out.sort_by_key(|(l, ..)| l.trim_start_matches('C').parse::<usize>().unwrap());
    out
}

/// Self-check: the ResNet-18 graph contains every Table 1 config.
pub fn check_table1_coverage(g: &Graph) -> Vec<&'static str> {
    let present: Vec<&str> = conv_workloads(g).iter().map(|(l, ..)| *l).collect();
    TABLE1.iter().map(|(n, ..)| *n).filter(|n| !present.contains(n)).collect()
}
