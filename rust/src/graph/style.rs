//! Fast style-transfer workload builder — the paper's second
//! evaluation scenario ("object classification **and style transfer**
//! on edge-class FPGAs"), mirroring [`super::resnet`].
//!
//! Architecture (Johnson et al.'s fast neural style network, adapted
//! to the int8 regime with deterministic synthetic weights — the
//! evaluation measures performance, not artistic merit):
//!
//! * two stride-2 down-convolutions,
//! * five residual blocks at the bottleneck resolution,
//! * two upsample+conv stages — the network's stride-2 *transposed*
//!   convolutions lowered as `Upsample2x → Conv2d` (the standard
//!   resize-convolution replacement), which reuses the existing
//!   conv2d emission core instead of needing a new GEMM emitter,
//! * a final wide conv back to 3 channels, and
//! * a microcoded requantization epilogue: `ShrImm` range compression
//!   followed by a `MinImm` clamp — expressed as tensor-ALU graph
//!   nodes instead of CPU fixups (the `Shr` / `Min` opcodes end to
//!   end).

use super::ir::{Graph, GraphError, Op};
use super::resnet::synth_conv_weights;
use crate::compiler::{Conv2dParams, Requant};

/// Requantization shift used by every style conv layer (same healthy
/// int8 band as [`super::resnet::LAYER_SHIFT`]).
pub const STYLE_SHIFT: u8 = 6;

/// Output epilogue: fixed-point range compression...
pub const OUT_SHIFT: u8 = 1;
/// ...and upper clamp of the final image (microcoded `MIN`).
pub const OUT_CLAMP: i16 = 100;

/// Build the default fast-style-transfer graph: 32x32 input, 16 base
/// channels. Small enough for seconds-scale simulation, deep enough to
/// exercise every operator class the pipeline adds.
pub fn style_transfer(n: usize, seed: u64) -> Result<Graph, GraphError> {
    style_net(n, 32, 16, seed)
}

/// Build a fast-style-transfer graph for batch size `n` over a
/// `size x size` RGB input with `base_c` stem channels (the bottleneck
/// runs at `2 * base_c`). `size` must be divisible by 4 (two stride-2
/// stages down, two 2x upsamplings back).
pub fn style_net(n: usize, size: usize, base_c: usize, seed: u64) -> Result<Graph, GraphError> {
    assert!(size % 4 == 0, "size must be divisible by 4 (two stride-2 stages)");
    let mut g = Graph::new();
    let rq = |relu: bool| Requant { shift: STYLE_SHIFT, relu };
    let mut wseed = seed;
    let mut next_seed = move || {
        wseed = wseed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        wseed
    };
    let c1 = base_c;
    let c2 = 2 * base_c;
    let (s2, s4) = (size / 2, size / 4);

    let input = g.add("input", Op::Input { shape: vec![n, 3, size, size] }, &[])?;

    // Two stride-2 down-convolutions. Like ResNet's C1, the first has
    // too few input channels to be worth offloading (the paper's
    // min-IC rule keeps it on the CPU).
    let pd1 = Conv2dParams { h: size, w: size, ic: 3, oc: c1, k: 3, s: 2, requant: rq(true) };
    let d1 = g.add("down1", Op::Conv2d { p: pd1 }, &[input])?;
    g.set_weights(d1, synth_conv_weights(next_seed(), c1, 3, 3));
    let pd2 = Conv2dParams { h: s2, w: s2, ic: c1, oc: c2, k: 3, s: 2, requant: rq(true) };
    let d2 = g.add("down2", Op::Conv2d { p: pd2 }, &[d1])?;
    g.set_weights(d2, synth_conv_weights(next_seed(), c2, c1, 3));

    // Five residual blocks at the bottleneck resolution (fast-style
    // blocks carry no activation after the residual add).
    let mut x = d2;
    for block in 0..5 {
        let name = |part: &str| format!("res{block}.{part}");
        let pa = Conv2dParams { h: s4, w: s4, ic: c2, oc: c2, k: 3, s: 1, requant: rq(true) };
        let a = g.add(name("conv1"), Op::Conv2d { p: pa }, &[x])?;
        g.set_weights(a, synth_conv_weights(next_seed(), c2, c2, 3));
        let pb = Conv2dParams { h: s4, w: s4, ic: c2, oc: c2, k: 3, s: 1, requant: rq(false) };
        let b = g.add(name("conv2"), Op::Conv2d { p: pb }, &[a])?;
        g.set_weights(b, synth_conv_weights(next_seed(), c2, c2, 3));
        x = g.add(name("add"), Op::Add, &[b, x])?;
    }

    // Two upsample+conv stages: stride-2 transposed convolutions
    // lowered as resize-convolution (`Upsample2x → Conv2d`).
    let u1 = g.add("up1.upsample", Op::Upsample2x, &[x])?;
    let pu1 = Conv2dParams { h: s2, w: s2, ic: c2, oc: c1, k: 3, s: 1, requant: rq(true) };
    let uc1 = g.add("up1.conv", Op::Conv2d { p: pu1 }, &[u1])?;
    g.set_weights(uc1, synth_conv_weights(next_seed(), c1, c2, 3));
    let u2 = g.add("up2.upsample", Op::Upsample2x, &[uc1])?;
    let pu2 = Conv2dParams { h: size, w: size, ic: c1, oc: c1, k: 3, s: 1, requant: rq(true) };
    let uc2 = g.add("up2.conv", Op::Conv2d { p: pu2 }, &[u2])?;
    g.set_weights(uc2, synth_conv_weights(next_seed(), c1, c1, 3));

    // Final wide conv back to RGB, then the requantization epilogue in
    // microcode: shift-based range compression + upper clamp.
    let po = Conv2dParams { h: size, w: size, ic: c1, oc: 3, k: 9, s: 1, requant: rq(false) };
    let out_conv = g.add("out.conv", Op::Conv2d { p: po }, &[uc2])?;
    g.set_weights(out_conv, synth_conv_weights(next_seed(), 3, c1, 9));
    let shr = g.add("out.shr", Op::ShrImm { shift: OUT_SHIFT }, &[out_conv])?;
    let _clamp = g.add("out.clamp", Op::MinImm { imm: OUT_CLAMP }, &[shr])?;

    g.validate()?;
    Ok(g)
}
