use super::resnet::*;
use super::*;
use crate::arch::VtaConfig;
use crate::compiler::{Conv2dParams, FusedStep, Requant};

fn conv_p(ic: usize, oc: usize) -> Conv2dParams {
    Conv2dParams { h: 8, w: 8, ic, oc, k: 3, s: 1, requant: Requant { shift: 6, relu: false } }
}

#[test]
fn graph_shape_inference() {
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let c = g.add("conv", Op::Conv2d { p: conv_p(16, 32) }, &[x]).unwrap();
    assert_eq!(g.nodes[c].shape, vec![1, 32, 8, 8]);
    let p = g.add("pool", Op::MaxPool { k: 2, s: 2, pad: 0 }, &[c]).unwrap();
    assert_eq!(g.nodes[p].shape, vec![1, 32, 4, 4]);
    let gap = g.add("gap", Op::GlobalAvgPool, &[p]).unwrap();
    assert_eq!(g.nodes[gap].shape, vec![1, 32]);
}

#[test]
fn graph_rejects_bad_wiring() {
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    // forward reference
    assert!(g.add("c", Op::Conv2d { p: conv_p(16, 16) }, &[5]).is_err());
    // channel mismatch
    assert!(g.add("c", Op::Conv2d { p: conv_p(32, 16) }, &[x]).is_err());
    // add shape mismatch
    let c1 = g.add("c1", Op::Conv2d { p: conv_p(16, 16) }, &[x]).unwrap();
    let c2 = g.add("c2", Op::Conv2d { p: conv_p(16, 32) }, &[x]).unwrap();
    assert!(g.add("add", Op::Add, &[c1, c2]).is_err());
}

#[test]
fn validate_checks_weights() {
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let c = g.add("conv", Op::Conv2d { p: conv_p(16, 16) }, &[x]).unwrap();
    assert!(matches!(g.validate(), Err(GraphError::MissingWeights(_))));
    g.set_weights(c, synth_conv_weights(1, 16, 16, 3));
    assert!(g.validate().is_ok());
}

#[test]
fn fusion_folds_relu_into_conv() {
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let c = g.add("conv", Op::Conv2d { p: conv_p(16, 16) }, &[x]).unwrap();
    g.set_weights(c, synth_conv_weights(1, 16, 16, 3));
    let r = g.add("relu", Op::Relu, &[c]).unwrap();
    let _p = g.add("pool", Op::MaxPool { k: 2, s: 2, pad: 0 }, &[r]).unwrap();

    let (fused, n) = fuse(g).unwrap();
    assert_eq!(n, 1);
    assert_eq!(fused.nodes.len(), 3); // input, conv+relu, pool
    match &fused.nodes[1].op {
        Op::Conv2d { p } => assert!(p.requant.relu),
        other => panic!("unexpected {other:?}"),
    }
    // Weights survived the rewrite.
    assert!(fused.weights(1).is_some());
    assert!(fused.validate().is_ok());
}

#[test]
fn fusion_keeps_relu_with_multiple_consumers() {
    // conv → relu, but conv also feeds an Add: ReLU must NOT fold.
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let c = g.add("conv", Op::Conv2d { p: conv_p(16, 16) }, &[x]).unwrap();
    g.set_weights(c, synth_conv_weights(1, 16, 16, 3));
    let r = g.add("relu", Op::Relu, &[c]).unwrap();
    let _a = g.add("add", Op::Add, &[r, c]).unwrap();
    let (fused, n) = fuse(g).unwrap();
    assert_eq!(n, 0);
    assert_eq!(fused.nodes.len(), 4);
}

#[test]
fn fusion_rejects_partitioned_graphs() {
    let cfg = VtaConfig::pynq();
    let mut g = resnet18(1, 42).unwrap();
    partition(&mut g, &PartitionPolicy::paper(&cfg));
    // Placements were silently reset to Unassigned before; now the
    // pass refuses — fusion must run before partitioning.
    assert!(matches!(fuse(g), Err(GraphError::AlreadyPartitioned(..))));
}

/// Node-for-node graph fingerprint for idempotence checks.
fn graph_sig(g: &Graph) -> Vec<String> {
    g.nodes
        .iter()
        .map(|n| format!("{}|{:?}|{:?}|{:?}|{:?}", n.name, n.op, n.inputs, n.shape, n.placement))
        .collect()
}

#[test]
fn fusion_is_idempotent() {
    use super::style::style_transfer;
    let builders: Vec<fn() -> Graph> = vec![
        || resnet18(1, 42).unwrap(),
        || style_transfer(1, 42).unwrap(),
        || {
            // A conv already carrying the relu flag followed by a
            // standalone ReLU: the fold must not re-append "+relu".
            let mut g = Graph::new();
            let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
            let mut p = conv_p(16, 16);
            p.requant.relu = true;
            let c = g.add("conv", Op::Conv2d { p }, &[x]).unwrap();
            g.set_weights(c, synth_conv_weights(1, 16, 16, 3));
            let _r = g.add("relu", Op::Relu, &[c]).unwrap();
            g
        },
    ];
    for build in builders {
        let (once, _) = fuse(build()).unwrap();
        let sig_once = graph_sig(&once);
        let (twice, n2) = fuse(once).unwrap();
        assert_eq!(n2, 0, "second pass must fuse nothing");
        assert_eq!(graph_sig(&twice), sig_once, "fuse(fuse(g)) != fuse(g)");
    }
}

#[test]
fn fusion_collapses_residual_chain() {
    // conv2 → add(residual) → relu collapses into one FusedConv2d with
    // the residual as a second input — the ResNet block tail.
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let c1 = g.add("c1", Op::Conv2d { p: conv_p(16, 16) }, &[x]).unwrap();
    g.set_weights(c1, synth_conv_weights(1, 16, 16, 3));
    let c2 = g.add("c2", Op::Conv2d { p: conv_p(16, 16) }, &[c1]).unwrap();
    g.set_weights(c2, synth_conv_weights(2, 16, 16, 3));
    let a = g.add("add", Op::Add, &[c2, x]).unwrap();
    let _r = g.add("relu", Op::Relu, &[a]).unwrap();

    let (fused, n) = fuse(g).unwrap();
    assert_eq!(n, 2, "add and relu fuse away");
    assert_eq!(fused.nodes.len(), 3); // input, c1, c2+add+relu
    let tail = &fused.nodes[2];
    assert_eq!(tail.name, "c2+add+relu");
    match &tail.op {
        Op::FusedConv2d { steps, .. } => {
            assert_eq!(steps, &[FusedStep::AddResidual, FusedStep::Relu]);
        }
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(tail.inputs, vec![1, 0], "conv input then residual");
    assert!(fused.weights(2).is_some(), "conv weights survive the rewrite");
    assert!(fused.validate().is_ok());
}

#[test]
fn fusion_collapses_shr_min_chain() {
    // conv → shr → min collapses into one FusedConv2d — the
    // style-transfer output stage.
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let c = g.add("c", Op::Conv2d { p: conv_p(16, 16) }, &[x]).unwrap();
    g.set_weights(c, synth_conv_weights(1, 16, 16, 3));
    let s = g.add("shr", Op::ShrImm { shift: 1 }, &[c]).unwrap();
    let _m = g.add("min", Op::MinImm { imm: 100 }, &[s]).unwrap();

    let (fused, n) = fuse(g).unwrap();
    assert_eq!(n, 2);
    assert_eq!(fused.nodes.len(), 2);
    match &fused.nodes[1].op {
        Op::FusedConv2d { steps, .. } => {
            assert_eq!(steps, &[FusedStep::ShrImm { shift: 1 }, FusedStep::MinImm { imm: 100 }]);
        }
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(fused.nodes[1].name, "c+shr+min");
}

#[test]
fn fusion_two_convs_joining_one_add() {
    // Both convs feed the same Add: the earlier conv claims the chain,
    // the later stays plain and becomes the residual input.
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let a = g.add("a", Op::Conv2d { p: conv_p(16, 16) }, &[x]).unwrap();
    g.set_weights(a, synth_conv_weights(1, 16, 16, 3));
    let b = g.add("b", Op::Conv2d { p: conv_p(16, 16) }, &[x]).unwrap();
    g.set_weights(b, synth_conv_weights(2, 16, 16, 3));
    let _s = g.add("sum", Op::Add, &[a, b]).unwrap();

    let (fused, n) = fuse(g).unwrap();
    assert_eq!(n, 1, "the add fuses into exactly one conv");
    assert_eq!(fused.nodes.len(), 3);
    // The fused node lands at the chain tail's (the Add's) topo
    // position, so the plain conv `b` — its residual input — precedes.
    assert_eq!(fused.nodes[1].name, "b");
    assert!(matches!(fused.nodes[1].op, Op::Conv2d { .. }), "b stays a plain conv");
    assert_eq!(fused.nodes[2].name, "a+add");
    assert!(matches!(fused.nodes[2].op, Op::FusedConv2d { .. }));
    assert_eq!(fused.nodes[2].inputs, vec![0, 1]);
    assert!(fused.validate().is_ok());
}

#[test]
fn resnet18_builds_and_covers_table1() {
    let g = resnet18(1, 42).unwrap();
    assert!(g.validate().is_ok());
    let missing = check_table1_coverage(&g);
    assert!(missing.is_empty(), "missing Table 1 configs: {missing:?}");
    // ~11.18 M int8 parameters (conv + fc, no BN since it's folded).
    let mb = g.param_bytes() as f64 / 1e6;
    assert!((10.0..13.0).contains(&mb), "unexpected param count: {mb} MB");
    // 21 conv nodes (1 stem + 16 block convs + 4 projections).
    let convs = g.nodes.iter().filter(|n| matches!(n.op, Op::Conv2d { .. })).count();
    assert_eq!(convs, 21);
}

#[test]
fn resnet18_workload_multiplicity() {
    let g = resnet18(1, 42).unwrap();
    let wl = conv_workloads(&g);
    assert_eq!(wl.len(), 12);
    // C2 (56x56 64→64 3x3) appears 4x in ResNet-18 (layer1 blocks,
    // plus layer2.0's second conv is C6 etc. — spot check C2 and C12).
    let c2 = wl.iter().find(|(l, ..)| *l == "C2").unwrap();
    assert_eq!(c2.2, 4);
    let c12 = wl.iter().find(|(l, ..)| *l == "C12").unwrap();
    assert_eq!(c12.2, 3);
}

#[test]
fn partition_follows_paper_policy() {
    let cfg = VtaConfig::pynq();
    let (mut g, _) = fuse(resnet18(1, 42).unwrap()).unwrap();
    let (vta, cpu) = partition(&mut g, &PartitionPolicy::paper(&cfg));
    // All convs except C1 (3 input channels < 16) offload.
    assert_eq!(vta, 20);
    assert!(cpu > 0);
    // C1 specifically is on the CPU.
    let c1 = g.nodes.iter().find(|n| n.name.starts_with("conv1")).unwrap();
    assert_eq!(c1.placement, Placement::Cpu);
    // fc / pools / adds on CPU.
    for n in &g.nodes {
        if matches!(n.op, Op::Dense { .. } | Op::MaxPool { .. } | Op::Add) {
            assert_eq!(n.placement, Placement::Cpu, "{}", n.name);
        }
    }
}

#[test]
fn partition_cpu_only_places_everything_on_cpu() {
    let mut g = resnet18(1, 42).unwrap();
    let (vta, _) = partition(&mut g, &PartitionPolicy::cpu_only());
    assert_eq!(vta, 0);
}

#[test]
fn synthetic_weights_are_deterministic() {
    assert_eq!(synth_conv_weights(7, 8, 8, 3), synth_conv_weights(7, 8, 8, 3));
    assert_ne!(synth_conv_weights(7, 8, 8, 3), synth_conv_weights(8, 8, 8, 3));
}

#[test]
fn saturating_add_semantics() {
    assert_eq!(Graph::saturating_add(100, 100), 127);
    assert_eq!(Graph::saturating_add(-100, -100), -128);
    assert_eq!(Graph::saturating_add(5, -3), 2);
}

// ---------------------------------------------------------------------
// Topological stages.
// ---------------------------------------------------------------------

#[test]
fn stages_respect_dependences_and_cover_all_nodes() {
    let g = resnet18(1, 42).unwrap();
    let st = stages(&g);
    let levels = node_stages(&g);

    // Every node appears exactly once, in its level's bucket.
    let mut seen = vec![false; g.nodes.len()];
    for (lvl, stage) in st.iter().enumerate() {
        for &id in stage {
            assert!(!seen[id], "node {id} appears twice");
            seen[id] = true;
            assert_eq!(levels[id], lvl);
        }
    }
    assert!(seen.iter().all(|&s| s), "stages must cover every node");

    // Every edge crosses strictly forward in stage order.
    for n in &g.nodes {
        for &i in &n.inputs {
            assert!(levels[i] < levels[n.id], "edge {i}→{} within/backward a stage", n.id);
        }
    }
}

#[test]
fn stages_of_a_chain_are_singletons() {
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let c = g.add("c", Op::Conv2d { p: conv_p(16, 16) }, &[x]).unwrap();
    let _p = g.add("p", Op::MaxPool { k: 2, s: 2, pad: 0 }, &[c]).unwrap();
    let st = stages(&g);
    assert_eq!(st.len(), 3);
    assert!(st.iter().all(|s| s.len() == 1));
}

#[test]
fn stages_put_parallel_branches_in_one_stage() {
    // Residual fork: conv main path and the shortcut projection share
    // the stage right after the input.
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let a = g.add("a", Op::Conv2d { p: conv_p(16, 16) }, &[x]).unwrap();
    let b = g.add("b", Op::Conv2d { p: conv_p(16, 16) }, &[x]).unwrap();
    let _s = g.add("sum", Op::Add, &[a, b]).unwrap();
    let st = stages(&g);
    assert_eq!(st.len(), 3);
    assert_eq!(st[1].len(), 2, "independent branches share a stage");
    assert_eq!(st[2], vec![3]);
}

#[test]
fn stages_of_empty_graph_is_empty() {
    assert!(stages(&Graph::new()).is_empty());
}
