//! The graph IR: nodes, operators, shape inference.

use crate::compiler::{Conv2dParams, FusedStep, MatmulParams, Requant};
use crate::util::Tensor;
use thiserror::Error;

/// Node identifier.
pub type NodeId = usize;

/// Tensor shape (NCHW for activations, `[M, N]` for matrices).
pub type TensorShape = Vec<usize>;

/// Graph construction / validation errors.
#[derive(Debug, Error)]
pub enum GraphError {
    #[error("node {0} references unknown input {1}")]
    UnknownInput(NodeId, NodeId),
    #[error("node {id} ({name}): shape mismatch: {detail}")]
    ShapeMismatch { id: NodeId, name: String, detail: String },
    #[error("graph has no output node")]
    NoOutput,
    #[error("missing weights for node {0}")]
    MissingWeights(NodeId),
    #[error("node {0} ({1}) is already placed; fuse() must run before partitioning")]
    AlreadyPartitioned(NodeId, String),
}

/// Where a node executes (decided by the partition pass).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Placement {
    /// Not yet decided.
    #[default]
    Unassigned,
    /// Offloaded to the VTA accelerator.
    Vta,
    /// Runs on the CPU (native Rust or an XLA/PJRT executable).
    Cpu,
}

/// Operators. Quantized int8 domain end-to-end: convolution and dense
/// accumulate in int32 and requantize on write-back (the paper's 8-bit
/// weight/activation, 32-bit accumulator regime, §2.5).
#[derive(Clone, Debug)]
pub enum Op {
    /// Graph input placeholder.
    Input { shape: TensorShape },
    /// 2D convolution (+ fused requant/ReLU epilogue).
    Conv2d { p: Conv2dParams },
    /// A conv with a fused epilogue chain (produced by
    /// [`crate::graph::fuse`]): the steps run in the conv's own ACC
    /// residency as extra tensor-ALU passes — no intermediate
    /// store/load. Inputs are `[x]` or `[x, residual]` when the chain
    /// carries an [`FusedStep::AddResidual`].
    FusedConv2d { p: Conv2dParams, steps: Vec<FusedStep> },
    /// Standalone ReLU (fused into producers where possible).
    Relu,
    /// Max pooling (CPU-resident in the paper's evaluation).
    MaxPool { k: usize, s: usize, pad: usize },
    /// Global average pooling → `[N, C]`.
    GlobalAvgPool,
    /// Residual addition with saturating int8 semantics (CPU-resident).
    Add,
    /// Dense / fully-connected layer (`x W^T`, requantized).
    Dense { p: MatmulParams },
    /// Element-wise minimum with a broadcast immediate (the clamping
    /// half of a microcoded requant epilogue; tensor-ALU `MIN`).
    MinImm { imm: i16 },
    /// Element-wise arithmetic shift-right (the scaling half of a
    /// microcoded requant epilogue; tensor-ALU `SHR`).
    ShrImm { shift: u8 },
    /// Nearest-neighbor 2x spatial upsampling over NCHW (the
    /// style-transfer resize-convolution block; a strided store/copy
    /// pass on the VTA).
    Upsample2x,
}

/// A graph node.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: Op,
    pub inputs: Vec<NodeId>,
    pub placement: Placement,
    /// Inferred output shape.
    pub shape: TensorShape,
}

/// A dataflow graph in topological order (nodes only reference earlier
/// nodes — enforced at construction).
#[derive(Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    /// Per-node parameter tensors (conv weights `OIHW`, dense `N x K`).
    weights: Vec<Option<Tensor<i8>>>,
}

impl Graph {
    /// Empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a node; `inputs` must be existing ids. Returns the id.
    pub fn add(
        &mut self,
        name: impl Into<String>,
        op: Op,
        inputs: &[NodeId],
    ) -> Result<NodeId, GraphError> {
        let id = self.nodes.len();
        for &i in inputs {
            if i >= id {
                return Err(GraphError::UnknownInput(id, i));
            }
        }
        let name = name.into();
        let shape = self.infer_shape(id, &name, &op, inputs)?;
        self.nodes.push(Node { id, name, op, inputs: inputs.to_vec(), placement: Placement::Unassigned, shape });
        self.weights.push(None);
        Ok(id)
    }

    /// Attach weights to a node.
    pub fn set_weights(&mut self, id: NodeId, w: Tensor<i8>) {
        self.weights[id] = Some(w);
    }

    /// Node weights, if any.
    pub fn weights(&self, id: NodeId) -> Option<&Tensor<i8>> {
        self.weights.get(id).and_then(|w| w.as_ref())
    }

    /// The output node (last appended).
    pub fn output(&self) -> Result<NodeId, GraphError> {
        if self.nodes.is_empty() {
            Err(GraphError::NoOutput)
        } else {
            Ok(self.nodes.len() - 1)
        }
    }

    /// Total parameter bytes.
    pub fn param_bytes(&self) -> usize {
        self.weights.iter().flatten().map(|w| w.len()).sum()
    }

    fn infer_shape(
        &self,
        id: NodeId,
        name: &str,
        op: &Op,
        inputs: &[NodeId],
    ) -> Result<TensorShape, GraphError> {
        let err = |detail: String| GraphError::ShapeMismatch { id, name: name.to_string(), detail };
        let in_shape = |i: usize| -> &TensorShape { &self.nodes[inputs[i]].shape };
        match op {
            Op::Input { shape } => Ok(shape.clone()),
            Op::Conv2d { p } => {
                let s = in_shape(0);
                if s.len() != 4 || s[1] != p.ic || s[2] != p.h || s[3] != p.w {
                    return Err(err(format!("conv expects [N,{},{},{}], got {s:?}", p.ic, p.h, p.w)));
                }
                Ok(vec![s[0], p.oc, p.out_h(), p.out_w()])
            }
            Op::FusedConv2d { p, steps } => {
                let s = in_shape(0);
                if s.len() != 4 || s[1] != p.ic || s[2] != p.h || s[3] != p.w {
                    return Err(err(format!("conv expects [N,{},{},{}], got {s:?}", p.ic, p.h, p.w)));
                }
                let out = vec![s[0], p.oc, p.out_h(), p.out_w()];
                let residuals = steps.iter().filter(|s| **s == FusedStep::AddResidual).count();
                if residuals > 1 {
                    return Err(err("fused chain carries more than one residual add".into()));
                }
                if inputs.len() != 1 + residuals {
                    return Err(err(format!(
                        "fused conv expects {} inputs, got {}",
                        1 + residuals,
                        inputs.len()
                    )));
                }
                if residuals == 1 && in_shape(1) != &out {
                    return Err(err(format!(
                        "residual shape {:?} differs from conv output {out:?}",
                        in_shape(1)
                    )));
                }
                Ok(out)
            }
            Op::Relu => Ok(in_shape(0).clone()),
            Op::MaxPool { k, s, pad } => {
                let sh = in_shape(0);
                if sh.len() != 4 {
                    return Err(err(format!("maxpool expects NCHW, got {sh:?}")));
                }
                let oh = (sh[2] + 2 * pad - k) / s + 1;
                let ow = (sh[3] + 2 * pad - k) / s + 1;
                Ok(vec![sh[0], sh[1], oh, ow])
            }
            Op::GlobalAvgPool => {
                let sh = in_shape(0);
                if sh.len() != 4 {
                    return Err(err(format!("gap expects NCHW, got {sh:?}")));
                }
                Ok(vec![sh[0], sh[1]])
            }
            Op::Add => {
                let (a, b) = (in_shape(0), in_shape(1));
                if a != b {
                    return Err(err(format!("add operands differ: {a:?} vs {b:?}")));
                }
                Ok(a.clone())
            }
            Op::Dense { p } => {
                let sh = in_shape(0);
                if sh.len() != 2 || sh[1] != p.k {
                    return Err(err(format!("dense expects [M,{}], got {sh:?}", p.k)));
                }
                Ok(vec![sh[0], p.n])
            }
            Op::MinImm { .. } | Op::ShrImm { .. } => Ok(in_shape(0).clone()),
            Op::Upsample2x => {
                let sh = in_shape(0);
                if sh.len() != 4 {
                    return Err(err(format!("upsample2x expects NCHW, got {sh:?}")));
                }
                Ok(vec![sh[0], sh[1], 2 * sh[2], 2 * sh[3]])
            }
        }
    }

    /// Consistency check: every parametric node has weights of the
    /// right shape.
    pub fn validate(&self) -> Result<(), GraphError> {
        for n in &self.nodes {
            match &n.op {
                Op::Conv2d { p } | Op::FusedConv2d { p, .. } => {
                    let w = self.weights(n.id).ok_or(GraphError::MissingWeights(n.id))?;
                    if w.shape() != [p.oc, p.ic, p.k, p.k] {
                        return Err(GraphError::ShapeMismatch {
                            id: n.id,
                            name: n.name.clone(),
                            detail: format!("conv weights {:?}", w.shape()),
                        });
                    }
                }
                Op::Dense { p } => {
                    let w = self.weights(n.id).ok_or(GraphError::MissingWeights(n.id))?;
                    if w.shape() != [p.n, p.k] {
                        return Err(GraphError::ShapeMismatch {
                            id: n.id,
                            name: n.name.clone(),
                            detail: format!("dense weights {:?}", w.shape()),
                        });
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Saturating int8 residual addition — the CPU-side semantics for
    /// `Op::Add` (shared with the JAX model).
    pub fn saturating_add(a: i8, b: i8) -> i8 {
        (a as i16 + b as i16).clamp(-128, 127) as i8
    }
}

impl Op {
    /// Short operator class name (reporting).
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Input { .. } => "input",
            Op::Conv2d { .. } => "conv2d",
            Op::FusedConv2d { .. } => "fused_conv2d",
            Op::Relu => "relu",
            Op::MaxPool { .. } => "maxpool",
            Op::GlobalAvgPool => "gap",
            Op::Add => "add",
            Op::Dense { .. } => "dense",
            Op::MinImm { .. } => "min",
            Op::ShrImm { .. } => "shr",
            Op::Upsample2x => "upsample2x",
        }
    }

    /// Integer-op count of the node (for Amdahl accounting).
    pub fn ops(&self, out_shape: &[usize]) -> u64 {
        match self {
            Op::Conv2d { p } => p.ops(),
            Op::FusedConv2d { p, steps } => {
                p.ops() + (steps.len() * out_shape.iter().product::<usize>()) as u64
            }
            Op::Dense { p } => p.ops(),
            Op::MaxPool { k, .. } => (out_shape.iter().product::<usize>() * k * k) as u64,
            Op::Add | Op::Relu | Op::MinImm { .. } | Op::ShrImm { .. } | Op::Upsample2x => {
                out_shape.iter().product::<usize>() as u64
            }
            Op::GlobalAvgPool | Op::Input { .. } => 0,
        }
    }

    /// The requant epilogue carried by this op, if fused.
    pub fn requant(&self) -> Option<Requant> {
        match self {
            Op::Conv2d { p } => Some(p.requant),
            Op::FusedConv2d { p, .. } => Some(p.requant),
            Op::Dense { p } => Some(p.requant),
            _ => None,
        }
    }
}
