//! NNVM-like graph layer (§1.2 "NNVM Intermediate Representation").
//!
//! A small dataflow IR over quantized int8 tensors with the passes the
//! paper's stack applies before TVM lowering:
//!
//! * [`fusion`] — operator fusion (conv + requant + ReLU collapse into
//!   the conv node's ALU epilogue, the fusion §1.2 motivates).
//! * [`partition`] — CPU / VTA placement (§5: conv layers offload
//!   except shallow-channel C1; pooling, FC, residual adds stay on the
//!   CPU).
//! * [`resnet`] — the ResNet-18 workload builder with deterministic
//!   synthetic int8 weights (Table 1's twelve conv configurations).
//! * [`style`] — the fast style-transfer workload builder (down-convs,
//!   residual blocks, `Upsample2x → Conv2d` resize-convolutions, and a
//!   microcoded requant epilogue) — the paper's second scenario.
//! * [`stages`] — topological (ASAP) stage computation, consumed by
//!   the pipelined serving executor in [`crate::exec::serve`].

mod fusion;
mod ir;
mod partition;
pub mod resnet;
mod stage;
pub mod style;

pub use fusion::fuse;
pub use ir::{Graph, GraphError, Node, NodeId, Op, Placement, TensorShape};
pub use partition::{partition, PartitionPolicy};
pub use stage::{node_stages, stages};

#[cfg(test)]
mod tests;
