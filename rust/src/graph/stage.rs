//! Topological stage computation for the pipelined executor.
//!
//! A *stage* is an ASAP level: stage 0 holds nodes with no inputs,
//! stage `k` holds nodes whose deepest input sits in stage `k - 1`.
//! Nodes within one stage are mutually independent, so the serving
//! layer may freely interleave them across the CPU and the accelerator
//! — the graph-granularity analogue of the ISA's decoupled
//! access-execute (§2.3): the token-checked *dependence* structure is
//! the stage DAG, the *resources* are the two heterogeneous executors.

use super::ir::{Graph, NodeId};

/// Partition the graph into topological stages (ASAP levels).
///
/// Returns one `Vec<NodeId>` per stage, in dependence order; the
/// concatenation of all stages is a permutation of all node ids, and
/// every edge goes from a strictly earlier stage to a later one.
pub fn stages(g: &Graph) -> Vec<Vec<NodeId>> {
    if g.nodes.is_empty() {
        return Vec::new();
    }
    let mut level = vec![0usize; g.nodes.len()];
    let mut max_level = 0usize;
    for n in &g.nodes {
        // Nodes only reference earlier ids (enforced at construction),
        // so a single forward sweep computes ASAP levels.
        let l = n.inputs.iter().map(|&i| level[i] + 1).max().unwrap_or(0);
        level[n.id] = l;
        max_level = max_level.max(l);
    }
    let mut out = vec![Vec::new(); max_level + 1];
    for n in &g.nodes {
        out[level[n.id]].push(n.id);
    }
    out
}

/// The stage index of every node (same leveling as [`stages`]).
pub fn node_stages(g: &Graph) -> Vec<usize> {
    let mut level = vec![0usize; g.nodes.len()];
    for n in &g.nodes {
        level[n.id] = n.inputs.iter().map(|&i| level[i] + 1).max().unwrap_or(0);
    }
    level
}
