//! CPU / VTA partitioning (§5 "End-to-end ResNet Evaluation"), driven
//! by the operator registry.
//!
//! The paper offloads every ResNet conv layer to the FPGA except C1
//! ("due to its low number of input channels"); residual adds, pooling
//! and the classifier run on the CPU. The policy here encodes exactly
//! that rule, parameterized so ablations can move the boundary — and
//! with the registry now lowering Dense and ALU-class elementwise ops,
//! the boundary can move all the way to "everything lowerable".
//!
//! The pass itself is op-generic: for every node it asks the node's
//! [`VtaOp`](crate::compiler::VtaOp) implementation three questions —
//! *can* it lower under this config
//! ([`offloadable`](crate::compiler::VtaOp::offloadable)), does the
//! policy *want* it on the VTA
//! ([`offload_policy`](crate::compiler::VtaOp::offload_policy)), and
//! is it *worth* it ([`cost`](crate::compiler::VtaOp::cost) against
//! [`PartitionPolicy::min_offload_ops`]). Adding an operator never
//! touches this file.

use super::ir::{Graph, Placement};
use crate::arch::VtaConfig;
use crate::compiler::op::op_impl;

/// Placement policy knobs.
#[derive(Clone, Debug)]
pub struct PartitionPolicy {
    /// Hardware variant placements are decided against (capability
    /// checks plan against it).
    pub cfg: VtaConfig,
    /// Virtual-thread count the executor will lower VTA nodes with
    /// (capability checks plan against it: vt=1 has twice the
    /// per-context SRAM budget of vt=2). Must match the
    /// `virtual_threads` of the `Executor` / `ServingEngine` the
    /// partitioned graph will run on — the CLI wires both to `--vt`.
    pub virtual_threads: usize,
    /// Minimum input channels for a conv to be worth offloading
    /// (paper: one full `BLOCK_IN`, which C1's 3 channels miss).
    pub min_conv_ic: usize,
    /// Offload dense layers too (paper: no — FC runs on the CPU).
    pub offload_dense: bool,
    /// Offload ALU-class elementwise ops (residual adds, standalone
    /// ReLUs, Min/Shr requant-epilogue steps) onto the tensor-ALU
    /// micro-op path.
    pub offload_alu: bool,
    /// Offload nearest-neighbor 2x upsampling (the style-transfer
    /// resize-convolution block) onto the strided store/copy pass.
    pub offload_upsample: bool,
    /// Nodes costing fewer integer ops than this stay on the CPU
    /// (offload overhead floor; 0 = no floor).
    pub min_offload_ops: u64,
    /// Force everything onto the CPU (the Fig 16 baseline).
    pub cpu_only: bool,
}

impl PartitionPolicy {
    /// The paper's evaluation policy for a given VTA variant.
    pub fn paper(cfg: &VtaConfig) -> Self {
        PartitionPolicy {
            cfg: cfg.clone(),
            virtual_threads: 2,
            min_conv_ic: cfg.gemm.block_in,
            offload_dense: false,
            offload_alu: false,
            offload_upsample: false,
            min_offload_ops: 0,
            cpu_only: false,
        }
    }

    /// Offload everything the registry can lower: convs (paper rule),
    /// dense layers, ALU-class elementwise ops, and upsampling.
    pub fn offload_all(cfg: &VtaConfig) -> Self {
        PartitionPolicy {
            offload_dense: true,
            offload_alu: true,
            offload_upsample: true,
            ..Self::paper(cfg)
        }
    }

    /// CPU-only baseline. The embedded `cfg` is a placeholder that
    /// [`partition`] never consults (the `cpu_only` flag
    /// short-circuits every capability check) — to re-enable offload,
    /// construct a fresh policy via [`Self::paper`] /
    /// [`Self::offload_all`] with the real hardware variant instead of
    /// clearing the flag on this one.
    pub fn cpu_only() -> Self {
        PartitionPolicy {
            cfg: VtaConfig::pynq(),
            virtual_threads: 2,
            min_conv_ic: usize::MAX,
            offload_dense: false,
            offload_alu: false,
            offload_upsample: false,
            min_offload_ops: 0,
            cpu_only: true,
        }
    }
}

/// Assign placements in-place. Returns (vta_nodes, cpu_nodes).
pub fn partition(g: &mut Graph, policy: &PartitionPolicy) -> (usize, usize) {
    let mut vta = 0;
    let mut cpu = 0;
    for n in &mut g.nodes {
        let entry = op_impl(&n.op);
        let place = if !policy.cpu_only
            && entry.offloadable(&policy.cfg, n, policy.virtual_threads)
            && entry.offload_policy(n, policy)
            && entry.cost(n) >= policy.min_offload_ops
        {
            Placement::Vta
        } else {
            Placement::Cpu
        };
        n.placement = place;
        match place {
            Placement::Vta => vta += 1,
            _ => cpu += 1,
        }
    }
    (vta, cpu)
}
