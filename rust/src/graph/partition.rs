//! CPU / VTA partitioning (§5 "End-to-end ResNet Evaluation").
//!
//! The paper offloads every ResNet conv layer to the FPGA except C1
//! ("due to its low number of input channels"); residual adds, pooling
//! and the classifier run on the CPU. The policy here encodes exactly
//! that rule, parameterized so ablations can move the boundary.

use super::ir::{Graph, Op, Placement};
use crate::arch::VtaConfig;

/// Placement policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct PartitionPolicy {
    /// Minimum input channels for a conv to be worth offloading
    /// (paper: one full `BLOCK_IN`, which C1's 3 channels miss).
    pub min_conv_ic: usize,
    /// Offload dense layers too (paper: no — FC runs on the CPU).
    pub offload_dense: bool,
    /// Force everything onto the CPU (the Fig 16 baseline).
    pub cpu_only: bool,
}

impl PartitionPolicy {
    /// The paper's evaluation policy for a given VTA variant.
    pub fn paper(cfg: &VtaConfig) -> Self {
        PartitionPolicy { min_conv_ic: cfg.gemm.block_in, offload_dense: false, cpu_only: false }
    }

    /// CPU-only baseline.
    pub fn cpu_only() -> Self {
        PartitionPolicy { min_conv_ic: usize::MAX, offload_dense: false, cpu_only: true }
    }
}

/// Assign placements in-place. Returns (vta_nodes, cpu_nodes).
pub fn partition(g: &mut Graph, policy: &PartitionPolicy) -> (usize, usize) {
    let mut vta = 0;
    let mut cpu = 0;
    for n in &mut g.nodes {
        let place = if policy.cpu_only {
            Placement::Cpu
        } else {
            match &n.op {
                Op::Conv2d { p } if p.ic >= policy.min_conv_ic => Placement::Vta,
                Op::Dense { .. } if policy.offload_dense => Placement::Vta,
                Op::Input { .. } => Placement::Cpu,
                _ => Placement::Cpu,
            }
        };
        n.placement = place;
        match place {
            Placement::Vta => vta += 1,
            _ => cpu += 1,
        }
    }
    (vta, cpu)
}
