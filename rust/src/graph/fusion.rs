//! Operator fusion (§1.2): the graph-level optimization NNVM performs
//! before TVM lowering, promoted to a general chain-matching pass.
//!
//! Two rewrites, both driven by the operator registry's fusion
//! capability ([`crate::compiler::VtaOp::fuse_step`] /
//! [`crate::compiler::VtaOp::anchors_fusion`]) rather than hard-coded
//! operator matches:
//!
//! 1. **ReLU folding** — a standalone `Relu` whose sole producer
//!    carries a requant epilogue (conv2d / dense,
//!    [`crate::compiler::VtaOp::folds_relu`]) sets the producer's
//!    `Requant::relu` flag: the `RQ_RELU` ALU opcode clamps at zero
//!    for free, no new node kind.
//! 2. **Epilogue chains** — a single-consumer chain hanging off a
//!    conv anchor, where every link describes itself as a
//!    [`FusedStep`] (`Add` → residual add, `Relu`, `ShrImm`,
//!    `MinImm`), collapses into one [`Op::FusedConv2d`] node. The
//!    compiler emits the whole chain as one `CompiledNode`: one ACC
//!    residency, the residual loaded into the accumulator and added
//!    via the tensor ALU, no intermediate store/load. This is the
//!    grammar that covers the ResNet block tail
//!    (`conv→add→relu`) and the style-transfer output stage
//!    (`conv→shr→min`).
//!
//! The pass runs on unpartitioned graphs only — placements are decided
//! *after* fusion (a fused node is offloaded or not as a unit), and
//! silently discarding placements was a bug. It is idempotent:
//! `fuse(fuse(g))` equals `fuse(g)` node for node.

use crate::compiler::{op_impl, FusedStep};

use super::ir::{Graph, GraphError, Node, Op, Placement};

/// Run the fusion pass. Returns the rewritten graph and the number of
/// nodes fused away. Errors if any node already has a placement —
/// fusion must run before [`super::partition`].
pub fn fuse(g: Graph) -> Result<(Graph, usize), GraphError> {
    for n in &g.nodes {
        if n.placement != Placement::Unassigned {
            return Err(GraphError::AlreadyPartitioned(n.id, n.name.clone()));
        }
    }

    // Count consumers of each node in the *original* graph.
    let mut consumers = vec![0usize; g.nodes.len()];
    let mut consumer_of: Vec<Vec<usize>> = vec![Vec::new(); g.nodes.len()];
    for n in &g.nodes {
        for &i in &n.inputs {
            consumers[i] += 1;
            consumer_of[i].push(n.id);
        }
    }

    // Phase 1: match maximal epilogue chains off every fusion anchor.
    // `chain_of[last_member] = Some(chain)`; every member (anchor
    // included) is marked consumed so the rewrite walk skips it until
    // the chain's last member, where the fused node is emitted.
    let mut consumed = vec![false; g.nodes.len()];
    let mut chain_at: Vec<Option<Chain>> = (0..g.nodes.len()).map(|_| None).collect();
    for n in &g.nodes {
        if !op_impl(&n.op).anchors_fusion() || consumers[n.id] != 1 {
            continue;
        }
        let mut steps: Vec<FusedStep> = Vec::new();
        let mut residual: Option<usize> = None;
        let mut members: Vec<usize> = Vec::new();
        let mut cur = n.id;
        // Extending past `cur` needs `cur` to have exactly one consumer
        // (its value must not escape the ACC residency), and that
        // consumer must not already belong to another chain (e.g. an
        // `Add` joining two convs — the earlier conv claims it, the
        // later one keeps it as its residual input).
        while consumers[cur] == 1 && !consumed[consumer_of[cur][0]] {
            let next = &g.nodes[consumer_of[cur][0]];
            let Some(step) = op_impl(&next.op).fuse_step(&next.op) else { break };
            if step == FusedStep::AddResidual {
                // The chain value must be exactly one operand; the
                // other operand (any consumer count) is the residual,
                // loaded into ACC alongside the conv's tiles. At most
                // one residual per chain — there is one spare half of
                // the ACC span.
                let others: Vec<usize> =
                    next.inputs.iter().copied().filter(|&i| i != cur).collect();
                if residual.is_some() || next.inputs.len() != 2 || others.len() != 1 {
                    break;
                }
                residual = Some(others[0]);
            }
            steps.push(step);
            members.push(next.id);
            cur = next.id;
        }
        // A lone ReLU is cheaper as a requant-flag fold (rewrite 1).
        if steps.is_empty() || steps == [FusedStep::Relu] {
            continue;
        }
        consumed[n.id] = true;
        for &m in &members {
            consumed[m] = true;
        }
        chain_at[cur] = Some(Chain { anchor: n.id, steps, residual, members });
    }

    // Phase 2: rewrite.
    let mut out = Graph::new();
    let mut remap: Vec<Option<usize>> = vec![None; g.nodes.len()];
    let mut fused = 0usize;

    for n in &g.nodes {
        if let Some(chain) = chain_at[n.id].take() {
            let anchor = &g.nodes[chain.anchor];
            let Op::Conv2d { p } = &anchor.op else {
                unreachable!("only conv anchors chains");
            };
            let mut name = anchor.name.clone();
            for s in &chain.steps {
                name.push_str(match s {
                    FusedStep::AddResidual => "+add",
                    FusedStep::Relu => "+relu",
                    FusedStep::ShrImm { .. } => "+shr",
                    FusedStep::MinImm { .. } => "+min",
                });
            }
            let mut inputs: Vec<usize> =
                anchor.inputs.iter().map(|&i| remap[i].expect("topo order")).collect();
            if let Some(res) = chain.residual {
                // The residual producer precedes the chain's last
                // member in topo order, so it is already emitted.
                inputs.push(remap[res].expect("residual precedes chain tail"));
            }
            let new_id = out
                .add(name, Op::FusedConv2d { p: *p, steps: chain.steps }, &inputs)
                .expect("rewrite preserves validity");
            if let Some(w) = g.weights(chain.anchor) {
                out.set_weights(new_id, w.clone());
            }
            remap[chain.anchor] = Some(new_id);
            for &m in &chain.members {
                remap[m] = Some(new_id);
            }
            fused += chain.members.len();
            continue;
        }
        if consumed[n.id] {
            continue; // emitted later, at its chain's last member
        }
        // Rewrite 1: fold a standalone ReLU into its sole producer's
        // requant epilogue. Idempotence: a producer already carrying
        // `relu` absorbs the (then no-op) ReLU without renaming.
        if matches!(n.op, Op::Relu) {
            let prod = n.inputs[0];
            if consumers[prod] == 1 && op_impl(&g.nodes[prod].op).folds_relu() {
                let new_prod = remap[prod].expect("producer already emitted");
                set_relu(&mut out.nodes[new_prod]);
                remap[n.id] = Some(new_prod);
                fused += 1;
                continue;
            }
        }
        let new_inputs: Vec<usize> =
            n.inputs.iter().map(|&i| remap[i].expect("topo order")).collect();
        let new_id = out
            .add(n.name.clone(), n.op.clone(), &new_inputs)
            .expect("rewrite preserves validity");
        if let Some(w) = g.weights(n.id) {
            out.set_weights(new_id, w.clone());
        }
        remap[n.id] = Some(new_id);
    }
    Ok((out, fused))
}

/// A matched epilogue chain: `anchor` (a conv) followed by `members`
/// (the absorbed nodes, in order), describing `steps`.
struct Chain {
    anchor: usize,
    steps: Vec<FusedStep>,
    residual: Option<usize>,
    members: Vec<usize>,
}

fn set_relu(node: &mut Node) {
    let requant = match &mut node.op {
        Op::Conv2d { p } => &mut p.requant,
        Op::Dense { p } => &mut p.requant,
        _ => unreachable!("checked by caller via folds_relu"),
    };
    if !requant.relu {
        requant.relu = true;
        node.name.push_str("+relu");
    }
}
