//! Operator fusion (§1.2): fold `Relu` nodes into their producer's
//! requant epilogue when the producer supports one (conv2d / dense).
//!
//! This is the graph-level optimization NNVM performs before TVM
//! lowering — on VTA it saves a full ALU pass plus a store/load round
//! trip per activation tensor.

use super::ir::{Graph, Node, Op, Placement};

/// Fuse ReLU into producers. Returns the rewritten graph and the number
/// of nodes fused away.
pub fn fuse(g: Graph) -> (Graph, usize) {
    // Count consumers of each node in the *original* graph.
    let mut consumers = vec![0usize; g.nodes.len()];
    for n in &g.nodes {
        for &i in &n.inputs {
            consumers[i] += 1;
        }
    }

    let mut out = Graph::new();
    // Map old id → new id.
    let mut remap: Vec<Option<usize>> = vec![None; g.nodes.len()];
    let mut fused = 0usize;

    for n in &g.nodes {
        // A ReLU whose single producer is a conv/dense that only it
        // consumes folds into that producer's requant.
        if matches!(n.op, Op::Relu) {
            let prod = n.inputs[0];
            let foldable = consumers[prod] == 1
                && matches!(g.nodes[prod].op, Op::Conv2d { .. } | Op::Dense { .. });
            if foldable {
                let new_prod = remap[prod].expect("producer already emitted");
                set_relu(&mut out.nodes[new_prod]);
                remap[n.id] = Some(new_prod);
                fused += 1;
                continue;
            }
        }
        let new_inputs: Vec<usize> =
            n.inputs.iter().map(|&i| remap[i].expect("topo order")).collect();
        let new_id = out
            .add(n.name.clone(), n.op.clone(), &new_inputs)
            .expect("rewrite preserves validity");
        out.nodes[new_id].placement = Placement::Unassigned;
        if let Some(w) = g.weights(n.id) {
            out.set_weights(new_id, w.clone());
        }
        remap[n.id] = Some(new_id);
    }
    (out, fused)
}

fn set_relu(node: &mut Node) {
    match &mut node.op {
        Op::Conv2d { p } => p.requant.relu = true,
        Op::Dense { p } => p.requant.relu = true,
        _ => unreachable!("checked by caller"),
    }
    node.name.push_str("+relu");
}
